//! Matmul kernels for the offline (coordinator-side) hot paths: rotation
//! fusion (W ← RᵀW), Hessian accumulation (XᵀX) in GPTQ, and the
//! sensitivity sweeps.
//!
//! Two kernel tiers live here:
//!
//! * **Packed-parallel** (the default): B is packed once per call into
//!   zero-padded column panels of [`NR`] floats, the M dimension is split
//!   across scoped threads ([`crate::util::par`]), and an [`MR`]×[`NR`]
//!   register-blocked microkernel accumulates each output tile with a
//!   fully unrolled inner loop the compiler auto-vectorizes. Per output
//!   element the k-loop runs ascending with a single accumulator, so
//!   results are bitwise identical for every thread count.
//! * **Scalar reference** (`*_ref`): the original single-threaded blocked
//!   kernels, kept verbatim as the baseline that `benches/kernels.rs`
//!   compares against (`BENCH_kernels.json`) and as the fallback for
//!   inputs too small to amortize packing.
//!
//! The Gram kernels exploit symmetry (upper triangle + mirror) in both
//! tiers and parallelize over *output* rows with a fixed row-block
//! accumulation order, which keeps them deterministic across thread
//! counts too.

use super::Tensor;
use crate::util::par::{self, num_threads, ParBackend};

/// Cache block size of the scalar reference kernel.
const BLOCK: usize = 64;
/// Column width of a packed B panel (microkernel accumulator lanes).
const NR: usize = 8;
/// Rows of A processed per microkernel invocation.
const MR: usize = 4;
/// Below this many multiply-adds the packed path's setup cost dominates
/// and the scalar reference kernel wins; keep tiny problems on it.
const PACK_MIN_MADDS: usize = 32 * 1024;
/// A-row block reused across one sweep of Gram output rows (L2 tiling).
const GRAM_ROW_BLOCK: usize = 64;
/// Minimum output rows per thread chunk (spawn amortization).
const MIN_ROWS_PER_CHUNK: usize = 8;
/// Square tile edge of the blocked transpose (32×32 f32 = 4 KiB: two
/// tiles — source + destination — sit comfortably in L1).
const TRANSPOSE_BLOCK: usize = 32;

/// C = A @ B for 2-D tensors (m,k) × (k,n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_with_threads(a, b, num_threads())
}

/// [`matmul`] with an explicit thread budget (tests / tuning).
pub fn matmul_with_threads(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    assert_eq!(a.rank(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.rank(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {:?} @ {:?}", a.shape, b.shape);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into_threads(&a.data, &b.data, &mut c.data, m, k, n, threads);
    c
}

/// C **+=** A @ B on raw row-major slices.
///
/// Contract: this *accumulates* into `c` — it never zeroes it. Callers
/// that want `C = A @ B` must pass a zeroed buffer (as [`matmul`] does);
/// callers that want streamed accumulation pass the running sum. Pinned
/// by `matmul_into_accumulates` below.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_into_threads(a, b, c, m, k, n, num_threads());
}

/// [`matmul_into`] with an explicit thread budget.
pub fn matmul_into_threads(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "matmul_into: lhs size");
    assert_eq!(b.len(), k * n, "matmul_into: rhs size");
    assert_eq!(c.len(), m * n, "matmul_into: out size");
    if m * k * n < PACK_MIN_MADDS {
        return matmul_into_ref(a, b, c, m, k, n);
    }
    let packed = pack_b(b, k, n, threads);
    par::par_row_chunks_mut(c, n, MIN_ROWS_PER_CHUNK, threads, |i0, cchunk| {
        let rows = cchunk.len() / n;
        matmul_packed_chunk(&a[i0 * k..(i0 + rows) * k], &packed, cchunk, rows, k, n);
    });
}

/// Parallel blocked transpose: `dst` (cols × rows) ← `src` (rows ×
/// cols). This is the epilogue for GEMM consumers that genuinely need a
/// row-major tensor from a column-major ([`matmul_into_colmajor`]-style)
/// output — RoPE/KV-append over the QKV projections, the GEMM lhs of
/// the R5 rotation — replacing the serial scalar flip the serving GEMMs
/// used to run. Work splits over destination rows; within a chunk the
/// copy walks [`TRANSPOSE_BLOCK`]² tiles so both sides stay
/// cache-resident. A pure data movement: bitwise exact by construction.
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32], threads: usize) {
    transpose_into_on(par::backend(), src, rows, cols, dst, threads);
}

/// [`transpose_into`] on an explicit parallel backend.
pub fn transpose_into_on(backend: ParBackend, src: &[f32], rows: usize, cols: usize, dst: &mut [f32], threads: usize) {
    assert_eq!(src.len(), rows * cols, "transpose_into: src size");
    assert_eq!(dst.len(), rows * cols, "transpose_into: dst size");
    if rows == 0 || cols == 0 {
        return;
    }
    if rows == 1 || cols == 1 {
        // a single row/column is the same sequence in either layout
        dst.copy_from_slice(src);
        return;
    }
    const TB: usize = TRANSPOSE_BLOCK;
    par::par_row_chunks_mut_on(backend, dst, rows, 1, threads, |j0, chunk| {
        let jn = chunk.len() / rows;
        for ib in (0..rows).step_by(TB) {
            let ie = (ib + TB).min(rows);
            for jb in (0..jn).step_by(TB) {
                let je = (jb + TB).min(jn);
                for j in jb..je {
                    let drow = &mut chunk[j * rows..(j + 1) * rows];
                    for i in ib..ie {
                        drow[i] = src[i * cols + j0 + j];
                    }
                }
            }
        }
    });
}

/// C_T **+=** (A @ B)ᵀ on raw slices: the column-major twin of
/// [`matmul_into`]. `c_t` is `(n × m)` — output column `j` of the
/// product occupies the contiguous run `c_t[j·m .. (j+1)·m]` — so a
/// consumer that traverses the product column-wise (or element-wise)
/// ingests it with no transpose at all. Per output element the k-loop
/// and accumulation order are identical to [`matmul_into`], so
/// `c_t[j·m + i]` is bitwise the row-major `c[i·n + j]` (routing
/// threshold included); pinned by `colmajor_matches_rowmajor_bitwise`.
pub fn matmul_into_colmajor(a: &[f32], b: &[f32], c_t: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_into_colmajor_threads(a, b, c_t, m, k, n, num_threads());
}

/// [`matmul_into_colmajor`] with an explicit thread budget.
pub fn matmul_into_colmajor_threads(a: &[f32], b: &[f32], c_t: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), m * k, "matmul_into_colmajor: lhs size");
    assert_eq!(b.len(), k * n, "matmul_into_colmajor: rhs size");
    assert_eq!(c_t.len(), m * n, "matmul_into_colmajor: out size");
    if m * k * n < PACK_MIN_MADDS {
        return matmul_into_colmajor_ref(a, b, c_t, m, k, n);
    }
    let packed = pack_b(b, k, n, threads);
    par::par_row_chunks_mut(c_t, m, NR, threads, |j0, chunk| {
        matmul_packed_colmajor_span::<true>(a, &packed, chunk, j0, m, k, n);
    });
}

/// Scalar reference for the column-major output: the exact loop nest of
/// [`matmul_into_ref`] (same blocking, same zero-skip, same per-element
/// k order) with the store transposed. Small-problem fallback.
fn matmul_into_colmajor_ref(a: &[f32], b: &[f32], c_t: &mut [f32], m: usize, k: usize, n: usize) {
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                for kk in k0..k1 {
                    let aik = a[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (j, bv) in brow.iter().enumerate() {
                        c_t[j * m + i] += aik * bv;
                    }
                }
            }
        }
    }
}

/// One chunk of the packed column-major GEMM: output columns
/// `[j0, j0 + chunk.len()/m)` of `(A@B)ᵀ`, written into `chunk` (column
/// `j` at `chunk[(j-j0)·m ..]`). Runs the exact [`microkernel`] tiles of
/// the row-major path and scatters the register tile transposed, so per
/// element the arithmetic is bit-identical; panels straddling a chunk
/// boundary are (cheaply) recomputed by both neighbors — each element is
/// still *stored* by exactly one chunk, with the same value.
fn matmul_packed_colmajor_span<const ACC: bool>(
    a: &[f32],
    packed: &[f32],
    chunk: &mut [f32],
    j0: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let jn = chunk.len() / m;
    let p0 = j0 / NR;
    let p1 = (j0 + jn + NR - 1) / NR;
    debug_assert!(p1 * k * NR <= packed.len());
    let mut i = 0;
    while i + MR <= m {
        let ar: [&[f32]; MR] = [
            &a[i * k..(i + 1) * k],
            &a[(i + 1) * k..(i + 2) * k],
            &a[(i + 2) * k..(i + 3) * k],
            &a[(i + 3) * k..(i + 4) * k],
        ];
        for p in p0..p1 {
            let panel = &packed[p * k * NR..(p + 1) * k * NR];
            let mut acc = [[0.0f32; NR]; MR];
            microkernel(&ar, panel, &mut acc);
            let jlo = (p * NR).max(j0);
            let jhi = (p * NR + NR).min(n).min(j0 + jn);
            for j in jlo..jhi {
                let col = j - p * NR;
                for (r, acc_r) in acc.iter().enumerate() {
                    let cv = &mut chunk[(j - j0) * m + i + r];
                    if ACC {
                        *cv += acc_r[col];
                    } else {
                        *cv = acc_r[col];
                    }
                }
            }
        }
        i += MR;
    }
    while i < m {
        let arow = &a[i * k..(i + 1) * k];
        for p in p0..p1 {
            let panel = &packed[p * k * NR..(p + 1) * k * NR];
            let mut acc = [0.0f32; NR];
            for (kk, bk) in panel.chunks_exact(NR).enumerate() {
                let bk: &[f32; NR] = bk.try_into().unwrap();
                let av = arow[kk];
                for j in 0..NR {
                    acc[j] += av * bk[j];
                }
            }
            let jlo = (p * NR).max(j0);
            let jhi = (p * NR + NR).min(n).min(j0 + jn);
            for j in jlo..jhi {
                let cv = &mut chunk[(j - j0) * m + i];
                if ACC {
                    *cv += acc[j - p * NR];
                } else {
                    *cv = acc[j - p * NR];
                }
            }
        }
        i += 1;
    }
}

/// Pack B (k×n row-major) into `ceil(n/NR)` contiguous column panels of
/// k×NR, zero-padding the last panel. Panels stream sequentially in the
/// microkernel's k-loop, so B is read prefetch-friendly exactly once per
/// MR-row group instead of strided once per scalar.
pub(crate) fn pack_b(b: &[f32], k: usize, n: usize, threads: usize) -> Vec<f32> {
    let np = (n + NR - 1) / NR;
    let mut packed = vec![0.0f32; np * k * NR];
    par::par_row_chunks_mut(&mut packed, k * NR, 1, threads, |p0, chunk| {
        for (pi, panel) in chunk.chunks_exact_mut(k * NR).enumerate() {
            let j0 = (p0 + pi) * NR;
            let jw = NR.min(n - j0);
            for kk in 0..k {
                panel[kk * NR..kk * NR + jw].copy_from_slice(&b[kk * n + j0..kk * n + j0 + jw]);
            }
        }
    });
    packed
}

/// One thread's share of the packed matmul: `rows` rows of A (contiguous
/// in `a`) against every panel of `packed`, accumulated into the matching
/// rows of `c`. Single-threaded by design so fused kernels can call it
/// from inside their own parallel regions without oversubscription.
pub(crate) fn matmul_packed_chunk(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
) {
    matmul_packed_chunk_impl::<true>(a, packed, c, rows, k, n);
}

/// Packed-chunk body, parameterized on the output contract: `ACC = true`
/// accumulates (`C += A@B`, the historical behavior), `ACC = false`
/// overwrites (`C = A@B`). Each output element is touched exactly once
/// per call (one panel, one row group), and the register tile starts at
/// `+0.0` — IEEE `+0.0 + x` reproduces `x` bitwise and a `+0.0`-seeded
/// sum can never round to `-0.0` — so overwriting a zeroed buffer and
/// accumulating into it are bitwise identical. That equivalence is what
/// lets [`PackedB::matmul_overwrite`] drop the pre-fill without
/// perturbing any decode stream (pinned by `overwrite_matches_zeroed_accumulate`).
fn matmul_packed_chunk_impl<const ACC: bool>(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
) {
    let np = (n + NR - 1) / NR;
    debug_assert_eq!(packed.len(), np * k * NR);
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(c.len(), rows * n);
    let mut i = 0;
    while i + MR <= rows {
        let ar: [&[f32]; MR] = [
            &a[i * k..(i + 1) * k],
            &a[(i + 1) * k..(i + 2) * k],
            &a[(i + 2) * k..(i + 3) * k],
            &a[(i + 3) * k..(i + 4) * k],
        ];
        for p in 0..np {
            let panel = &packed[p * k * NR..(p + 1) * k * NR];
            let mut acc = [[0.0f32; NR]; MR];
            microkernel(&ar, panel, &mut acc);
            let j0 = p * NR;
            let jw = NR.min(n - j0);
            for (r, acc_r) in acc.iter().enumerate() {
                let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + jw];
                for (cv, av) in crow.iter_mut().zip(&acc_r[..jw]) {
                    if ACC {
                        *cv += *av;
                    } else {
                        *cv = *av;
                    }
                }
            }
        }
        i += MR;
    }
    while i < rows {
        let arow = &a[i * k..(i + 1) * k];
        for p in 0..np {
            let panel = &packed[p * k * NR..(p + 1) * k * NR];
            let mut acc = [0.0f32; NR];
            for (kk, bk) in panel.chunks_exact(NR).enumerate() {
                let bk: &[f32; NR] = bk.try_into().unwrap();
                let av = arow[kk];
                for j in 0..NR {
                    acc[j] += av * bk[j];
                }
            }
            let j0 = p * NR;
            let jw = NR.min(n - j0);
            for (cv, av) in c[i * n + j0..i * n + j0 + jw].iter_mut().zip(&acc[..jw]) {
                if ACC {
                    *cv += *av;
                } else {
                    *cv = *av;
                }
            }
        }
        i += 1;
    }
}

/// A `(k, n)` matrix pre-packed into [`pack_b`] column panels, for GEMM
/// sites that multiply against the *same* B every call (the serve
/// engine's online rotations, the logits head, dense-f32 serving
/// weights). [`matmul_into_threads`] re-packs B on every invocation —
/// one `k×n`-float allocation plus a full copy per call — which is pure
/// overhead once B is a fixture; packing once at model build removes
/// both from the decode hot loop.
///
/// [`Self::matmul_overwrite`] keeps the exact routing of
/// [`matmul_into_threads`]: problems under [`PACK_MIN_MADDS`] run the
/// scalar reference kernel on the caller's dense copy of B (zero-filled
/// first, matching the historical `fill(0) → accumulate` call shape),
/// larger ones hit the packed microkernel with an overwriting store.
/// Both produce bitwise-identical output to `fill(0)` +
/// `matmul_into_threads` at every thread count (see
/// [`matmul_packed_chunk_impl`] for why the overwrite store is safe).
#[derive(Clone, Debug)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    packed: Vec<f32>,
}

impl PackedB {
    /// Pack a dense row-major `(k, n)` matrix once.
    pub fn pack(b: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(b.len(), k * n, "PackedB::pack: matrix size");
        assert!(k > 0 && n > 0, "PackedB::pack: empty matrix");
        Self { k, n, packed: pack_b(b, k, n, num_threads()) }
    }

    /// Panel-cache bytes held by the packed copy.
    pub fn bytes(&self) -> usize {
        self.packed.len() * 4
    }

    /// `c = a @ B` (overwrites `c`). `b_dense` must be the same matrix
    /// handed to [`Self::pack`] — the small-problem path reads it so the
    /// reference-kernel routing of [`matmul_into_threads`] is preserved
    /// bit-for-bit; callers always have it (it's the weight they packed).
    pub fn matmul_overwrite(
        &self,
        a: &[f32],
        b_dense: &[f32],
        c: &mut [f32],
        m: usize,
        threads: usize,
    ) {
        self.matmul_overwrite_on(par::backend(), a, b_dense, c, m, threads);
    }

    /// [`Self::matmul_overwrite`] on an explicit parallel backend (the
    /// serve engine pins one per `ServeConfig::par_backend`).
    pub fn matmul_overwrite_on(
        &self,
        backend: ParBackend,
        a: &[f32],
        b_dense: &[f32],
        c: &mut [f32],
        m: usize,
        threads: usize,
    ) {
        let (k, n) = (self.k, self.n);
        assert_eq!(a.len(), m * k, "PackedB matmul: lhs size");
        assert_eq!(b_dense.len(), k * n, "PackedB matmul: dense B size");
        assert_eq!(c.len(), m * n, "PackedB matmul: out size");
        if m * k * n < PACK_MIN_MADDS {
            c.fill(0.0);
            return matmul_into_ref(a, b_dense, c, m, k, n);
        }
        par::par_row_chunks_mut_on(backend, c, n, MIN_ROWS_PER_CHUNK, threads, |i0, cchunk| {
            let rows = cchunk.len() / n;
            matmul_packed_chunk_impl::<false>(
                &a[i0 * k..(i0 + rows) * k],
                &self.packed,
                cchunk,
                rows,
                k,
                n,
            );
        });
    }

    /// `c_t = (a @ B)ᵀ` (overwrites `c_t`, `n × m` column-major output).
    ///
    /// The row-major [`Self::matmul_overwrite`] splits work over the `m`
    /// output *rows* — at decode batch sizes (m ≤ 16 lanes) that caps
    /// parallelism at m chunks and makes every chunk stream the whole
    /// packed B. This variant splits over the `n` output *columns*
    /// instead: each packed panel is read by exactly one chunk, B
    /// traffic drops from `threads × k·n` to `k·n`, and the serving
    /// consumer (logits argmax/sampling) ingests the column-major block
    /// directly. Per element it runs the same [`microkernel`] tiles in
    /// the same k order, so `c_t[j·m + i]` is bitwise the row-major
    /// `c[i·n + j]` on both sides of the routing threshold.
    pub fn matmul_colmajor(&self, a: &[f32], b_dense: &[f32], c_t: &mut [f32], m: usize, threads: usize) {
        self.matmul_colmajor_on(par::backend(), a, b_dense, c_t, m, threads);
    }

    /// [`Self::matmul_colmajor`] on an explicit parallel backend.
    pub fn matmul_colmajor_on(
        &self,
        backend: ParBackend,
        a: &[f32],
        b_dense: &[f32],
        c_t: &mut [f32],
        m: usize,
        threads: usize,
    ) {
        let (k, n) = (self.k, self.n);
        assert_eq!(a.len(), m * k, "PackedB matmul: lhs size");
        assert_eq!(b_dense.len(), k * n, "PackedB matmul: dense B size");
        assert_eq!(c_t.len(), m * n, "PackedB matmul: out size");
        if m * k * n < PACK_MIN_MADDS {
            c_t.fill(0.0);
            return matmul_into_colmajor_ref(a, b_dense, c_t, m, k, n);
        }
        par::par_row_chunks_mut_on(backend, c_t, m, NR, threads, |j0, chunk| {
            matmul_packed_colmajor_span::<false>(a, &self.packed, chunk, j0, m, k, n);
        });
    }
}

/// MR×NR register tile: acc[r][j] += Σ_kk a[r][kk]·panel[kk][j], with the
/// r/j loops fully unrolled (const bounds) so LLVM keeps the tile in
/// vector registers and the panel row load is shared across MR rows.
#[inline(always)]
fn microkernel(ar: &[&[f32]; MR], panel: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (kk, bk) in panel.chunks_exact(NR).enumerate() {
        let bk: &[f32; NR] = bk.try_into().unwrap();
        for r in 0..MR {
            let av = ar[r][kk];
            for j in 0..NR {
                acc[r][j] += av * bk[j];
            }
        }
    }
}

/// Width of one [`dot_i8_i32`] tile: 16 code pairs per iteration.
const I8_TILE: usize = 16;
/// Independent i32 accumulator lanes inside a tile (4 codes each).
const I8_LANES: usize = 4;

/// Integer dot with an i32 accumulator — the inner microkernel of the
/// INT4×INT4 serving GEMM (`serve::Int4Weight::matmul_i8_into`).
///
/// Both operands are signed levels (activation codes on the per-row
/// fake-quant grid, weight codes unpacked from nibbles or read from the
/// cached i8 panel), so the sum is **exact**: no rounding happens until
/// the caller folds the f32 scales. Integer addition is associative, so
/// the reduction runs as an explicit fixed-width tile — [`I8_TILE`]
/// elements per step, split across [`I8_LANES`] independent i32
/// accumulator lanes with fully unrolled (const-bound) inner loops —
/// the shape LLVM reliably lowers to widening-multiply SIMD
/// (`pmaddwd`/`sdot`-style) instead of a serial add chain. The f32
/// dequant dot cannot do this: it must keep one serial fadd chain for
/// bitwise determinism and stays scalar. Any lane/tile split yields the
/// same exact integer, so results are unchanged from the scalar loop.
/// Overflow-safe for any realistic width: |a·b| ≤ 127·127 < 2¹⁴, so i32
/// is exact up to 2¹⁷ elements per call (serving rows are ≤ 2¹³).
#[inline]
pub fn dot_i8_i32(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    const SUB: usize = I8_TILE / I8_LANES;
    let mut lanes = [0i32; I8_LANES];
    let mut ach = a.chunks_exact(I8_TILE);
    let mut bch = b.chunks_exact(I8_TILE);
    for (ca, cb) in ach.by_ref().zip(bch.by_ref()) {
        let ca: &[i8; I8_TILE] = ca.try_into().unwrap();
        let cb: &[i8; I8_TILE] = cb.try_into().unwrap();
        for l in 0..I8_LANES {
            let mut s = 0i32;
            for e in 0..SUB {
                let i = l * SUB + e;
                s += ca[i] as i32 * cb[i] as i32;
            }
            lanes[l] += s;
        }
    }
    let mut acc = 0i32;
    for (&x, &w) in ach.remainder().iter().zip(bch.remainder()) {
        acc += x as i32 * w as i32;
    }
    for l in lanes {
        acc += l;
    }
    acc
}

/// Grouped integer dot with the scale fold: per scale group `g`,
/// `Σ_{i∈g} xq_i·wq_i` accumulates exactly in i32 via [`dot_i8_i32`],
/// then folds `act_scale · wscale_g` **once** per group:
///
/// `out = Σ_g (act_scale · wscale_g) · (Σ_{i∈g} xq_i · wq_i)`
///
/// Groups run ascending with a single f32 accumulator, so the result is
/// a pure function of the codes and scales — bitwise identical across
/// thread counts and batch sizes. Versus the f32 dequant path
/// (`Σ_g wscale_g · Σ_{i∈g} (xq_i·act_scale)·wq_i` in f32) the only
/// delta is f32 summation order inside a group; the quantized codes are
/// identical (pinned by `tests/props.rs`).
#[inline]
pub fn dot_i8_grouped(xq: &[i8], wq: &[i8], wscales: &[f32], group: usize, act_scale: f32) -> f32 {
    let k = xq.len();
    debug_assert_eq!(wq.len(), k);
    debug_assert!(group * wscales.len() >= k, "scale groups must cover the row");
    let mut acc = 0.0f32;
    for (g, &ws) in wscales.iter().enumerate() {
        let i0 = g * group;
        if i0 >= k {
            break;
        }
        let i1 = (i0 + group).min(k);
        let part = dot_i8_i32(&xq[i0..i1], &wq[i0..i1]);
        acc += (act_scale * ws) * part as f32;
    }
    acc
}

/// Scalar reference: the original cache-blocked i-k-j kernel, single
/// threaded. Kept as the `BENCH_kernels.json` baseline and the
/// small-input fallback. Same `C += A @ B` accumulate contract.
pub fn matmul_into_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = a[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// C = Aᵀ @ A (Gram / GPTQ Hessian accumulation), exploiting symmetry.
pub fn gram(a: &Tensor) -> Tensor {
    gram_with_threads(a, num_threads())
}

/// [`gram`] with an explicit thread budget.
pub fn gram_with_threads(a: &Tensor, threads: usize) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut c = Tensor::zeros(&[n, n]);
    if m == 0 || n == 0 {
        return c;
    }
    gram_upper_into(&a.data, m, n, &mut c.data, threads);
    mirror_lower(&mut c.data, n);
    c
}

/// Accumulate Aᵀ@A into an existing (n,n) Hessian (streamed batches).
///
/// Contract: `h` must be symmetric on entry (it is whenever it was built
/// by `gram`/`gram_accumulate` from a zeroed buffer). Only the upper
/// triangle is accumulated — half the multiply-adds of the full-row
/// update — and the lower triangle is restored by mirroring at the end.
pub fn gram_accumulate(h: &mut Tensor, a: &Tensor) {
    gram_accumulate_with_threads(h, a, num_threads());
}

/// [`gram_accumulate`] with an explicit thread budget. Accepts any
/// `(…, n)` tensor — leading axes are flattened into rows.
pub fn gram_accumulate_with_threads(h: &mut Tensor, a: &Tensor, threads: usize) {
    let (m, n) = a.as_2d();
    assert_eq!(h.shape, vec![n, n]);
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(debug_assertions)]
    {
        let sym = (0..n).all(|i| (0..i).all(|j| h.data[i * n + j] == h.data[j * n + i]));
        assert!(sym, "gram_accumulate needs a symmetric accumulator");
    }
    gram_upper_into(&a.data, m, n, &mut h.data, threads);
    mirror_lower(&mut h.data, n);
}

/// Accumulate `gram(rmsnorm(x))` into `h` without materializing the
/// normed activation copy — the fused form of
/// `gram_accumulate(h, rmsnorm_rows(x))` that `HessianSet::accumulate`
/// runs on every captured batch (the last hot path that still built a
/// full normed tensor).
///
/// Per-row inverse-RMS factors are computed once (same expression as
/// `model::capture::rmsnorm_row`: `1/√(mean(x²)+1e-5)`, weightless),
/// then each thread norms one [`GRAM_ROW_BLOCK`]-row slab into a local
/// buffer and runs the standard upper-triangle update from it. The
/// normed values and their accumulation order are identical to the
/// two-step path, so the result is **bitwise equal** to it at every
/// thread count; peak extra memory is `GRAM_ROW_BLOCK × n` floats per
/// thread instead of a whole `(m, n)` tensor.
pub fn gram_accumulate_rmsnorm(h: &mut Tensor, x: &Tensor) {
    gram_accumulate_rmsnorm_with_threads(h, x, num_threads());
}

/// [`gram_accumulate_rmsnorm`] with an explicit thread budget.
pub fn gram_accumulate_rmsnorm_with_threads(h: &mut Tensor, x: &Tensor, threads: usize) {
    let (m, n) = x.as_2d();
    assert_eq!(h.shape, vec![n, n]);
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(debug_assertions)]
    {
        let sym = (0..n).all(|i| (0..i).all(|j| h.data[i * n + j] == h.data[j * n + i]));
        assert!(sym, "gram_accumulate_rmsnorm needs a symmetric accumulator");
    }
    let mut inv = vec![0.0f32; m];
    par::par_row_chunks_mut(&mut inv, 1, 256, threads, |r0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let row = &x.data[(r0 + i) * n..(r0 + i + 1) * n];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / n as f32;
            *o = 1.0 / (ms + 1e-5).sqrt();
        }
    });
    par::par_row_chunks_mut(&mut h.data, n, MIN_ROWS_PER_CHUNK, threads, |i0, cchunk| {
        let ni = cchunk.len() / n;
        let mut nb = vec![0.0f32; GRAM_ROW_BLOCK.min(m) * n];
        for rb in (0..m).step_by(GRAM_ROW_BLOCK) {
            let rend = (rb + GRAM_ROW_BLOCK).min(m);
            for (bi, row) in (rb..rend).enumerate() {
                let s = inv[row];
                for (o, &v) in
                    nb[bi * n..(bi + 1) * n].iter_mut().zip(&x.data[row * n..(row + 1) * n])
                {
                    *o = v * s;
                }
            }
            for ii in 0..ni {
                let i = i0 + ii;
                let crow = &mut cchunk[ii * n + i..(ii + 1) * n];
                for bi in 0..rend - rb {
                    let ri = nb[bi * n + i];
                    if ri == 0.0 {
                        continue;
                    }
                    let arow = &nb[bi * n + i..(bi + 1) * n];
                    for (cv, av) in crow.iter_mut().zip(arow) {
                        *cv += ri * av;
                    }
                }
            }
        }
    });
    mirror_lower(&mut h.data, n);
}

/// Upper-triangle Gram accumulation, parallel over *output* rows.
///
/// Each thread owns a disjoint range of output rows i; for fixed i the
/// input rows are consumed in ascending order within ascending fixed-size
/// row blocks, so the accumulation order per element never depends on the
/// thread partition (determinism), while the row block keeps a hot slab
/// of A in cache across the chunk's output rows (locality).
fn gram_upper_into(a: &[f32], m: usize, n: usize, c: &mut [f32], threads: usize) {
    par::par_row_chunks_mut(c, n, MIN_ROWS_PER_CHUNK, threads, |i0, cchunk| {
        let ni = cchunk.len() / n;
        for rb in (0..m).step_by(GRAM_ROW_BLOCK) {
            let rend = (rb + GRAM_ROW_BLOCK).min(m);
            for ii in 0..ni {
                let i = i0 + ii;
                let crow = &mut cchunk[ii * n + i..(ii + 1) * n];
                for row in rb..rend {
                    let ri = a[row * n + i];
                    if ri == 0.0 {
                        continue;
                    }
                    let arow = &a[row * n + i..(row + 1) * n];
                    for (cv, av) in crow.iter_mut().zip(arow) {
                        *cv += ri * av;
                    }
                }
            }
        }
    });
}

/// Copy the upper triangle onto the lower one.
fn mirror_lower(c: &mut [f32], n: usize) {
    for i in 1..n {
        for j in 0..i {
            c[i * n + j] = c[j * n + i];
        }
    }
}

/// Scalar reference Gram (original single-threaded kernel; bench baseline).
pub fn gram_ref(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut c = Tensor::zeros(&[n, n]);
    for row in 0..m {
        let r = &a.data[row * n..(row + 1) * n];
        for i in 0..n {
            let ri = r[i];
            if ri == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in i..n {
                crow[j] += ri * r[j];
            }
        }
    }
    mirror_lower(&mut c.data, n);
    c
}

/// y = x @ W for a batch of rows (x: (m,k) flattened leading dims).
pub fn rows_matmul(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.as_2d();
    assert_eq!(w.rank(), 2);
    assert_eq!(w.shape[0], k);
    let n = w.shape[1];
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(&x.data, &w.data, &mut out.data, m, k, n);
    let mut shape = x.shape.clone();
    *shape.last_mut().unwrap() = n;
    out.reshape(&shape)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.data[i * k + kk] * b.data[kk * n + j];
                }
                c.data[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(0);
        for (m, k, n) in [(3, 4, 5), (65, 67, 63), (128, 128, 128), (1, 200, 1)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn packed_path_matches_naive_at_unaligned_shapes() {
        // shapes chosen to land above PACK_MIN_MADDS with every remainder
        // class: odd n (panel padding), m % MR ≠ 0 (row remainder), odd k
        let mut rng = Rng::new(42);
        for (m, k, n) in [(37, 41, 43), (130, 65, 33), (41, 129, 67), (129, 31, 129)] {
            assert!(m * k * n >= PACK_MIN_MADDS, "{m}x{k}x{n} too small to hit packed path");
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            for threads in [1usize, 3, 8] {
                let got = matmul_with_threads(&a, &b, threads);
                let want = naive(&a, &b);
                assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n} t={threads}");
            }
        }
    }

    #[test]
    fn matmul_into_accumulates() {
        // the documented contract: C += A@B, never C = A@B
        let mut rng = Rng::new(7);
        for (m, k, n) in [(5, 6, 7), (40, 40, 40)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let want = naive(&a, &b);
            let mut c = vec![0.25f32; m * n];
            matmul_into(&a.data, &b.data, &mut c, m, k, n);
            for (got, want) in c.iter().zip(&want.data) {
                assert!((got - (want + 0.25)).abs() < 1e-3, "accumulate contract broken");
            }
            // and a second call keeps accumulating
            matmul_into(&a.data, &b.data, &mut c, m, k, n);
            for (got, want) in c.iter().zip(&want.data) {
                assert!((got - (2.0 * want + 0.25)).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn ref_and_packed_agree() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (70, 64, 50);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c_ref = vec![0.0f32; m * n];
        matmul_into_ref(&a.data, &b.data, &mut c_ref, m, k, n);
        let c_packed = matmul_with_threads(&a, &b, 4);
        let diff = c_ref
            .iter()
            .zip(&c_packed.data)
            .fold(0.0f32, |acc, (x, y)| acc.max((x - y).abs()));
        assert!(diff < 1e-3, "ref vs packed diff {diff}");
    }

    #[test]
    fn overwrite_matches_zeroed_accumulate() {
        // PackedB::matmul_overwrite must be bitwise equal to the
        // historical fill(0) → matmul_into_threads call shape on both
        // sides of the PACK_MIN_MADDS routing threshold
        let mut rng = Rng::new(13);
        for (m, k, n) in [(3usize, 10, 7), (5, 64, 64), (37, 41, 43), (16, 256, 129)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let pb = PackedB::pack(&b.data, k, n);
            for threads in [1usize, 4] {
                let mut want = vec![0.0f32; m * n];
                matmul_into_threads(&a.data, &b.data, &mut want, m, k, n, threads);
                let mut got = vec![0.7f32; m * n]; // stale garbage must vanish
                pb.matmul_overwrite(&a.data, &b.data, &mut got, m, threads);
                assert_eq!(got, want, "{m}x{k}x{n} t={threads}");
            }
        }
    }

    #[test]
    fn transpose_into_matches_naive() {
        let mut rng = Rng::new(17);
        for (r, c) in [(1usize, 1usize), (1, 9), (9, 1), (3, 5), (16, 33), (65, 64), (129, 7)] {
            let src = Tensor::randn(&[r, c], 1.0, &mut rng);
            for threads in [1usize, 4] {
                for backend in [crate::util::par::ParBackend::Static, crate::util::par::ParBackend::Steal] {
                    let mut dst = vec![f32::NAN; r * c]; // stale garbage must vanish
                    transpose_into_on(backend, &src.data, r, c, &mut dst, threads);
                    for i in 0..r {
                        for j in 0..c {
                            assert_eq!(dst[j * r + i], src.data[i * c + j], "{r}x{c} t={threads} ({i},{j})");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn colmajor_matches_rowmajor_bitwise() {
        // matmul_into_colmajor must be the exact transpose of matmul_into
        // on both sides of the PACK_MIN_MADDS routing threshold (the
        // same per-element kernel runs, only the store index changes)
        let mut rng = Rng::new(19);
        for (m, k, n) in [(3usize, 10, 7), (5, 64, 64), (37, 41, 43), (16, 256, 129), (1, 40, 9)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            for threads in [1usize, 4] {
                let mut want = vec![0.1f32; m * n];
                matmul_into_threads(&a.data, &b.data, &mut want, m, k, n, threads);
                let mut got_t = vec![0.0f32; m * n];
                // seed with the transposed prior content so the
                // accumulate contract is exercised too
                for i in 0..m {
                    for j in 0..n {
                        got_t[j * m + i] = 0.1;
                    }
                }
                matmul_into_colmajor_threads(&a.data, &b.data, &mut got_t, m, k, n, threads);
                for i in 0..m {
                    for j in 0..n {
                        assert_eq!(got_t[j * m + i], want[i * n + j], "{m}x{k}x{n} t={threads} ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_colmajor_matches_overwrite_bitwise() {
        // PackedB::matmul_colmajor must be the exact transpose of
        // matmul_overwrite at every thread count and backend, on both
        // routing classes
        let mut rng = Rng::new(23);
        for (m, k, n) in [(3usize, 10, 7), (16, 64, 64), (16, 256, 129), (1, 31, 17)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let pb = PackedB::pack(&b.data, k, n);
            let mut want = vec![0.7f32; m * n];
            pb.matmul_overwrite(&a.data, &b.data, &mut want, m, 1);
            for threads in [1usize, 4] {
                for backend in [crate::util::par::ParBackend::Static, crate::util::par::ParBackend::Steal] {
                    let mut got_t = vec![0.7f32; m * n]; // stale garbage must vanish
                    pb.matmul_colmajor_on(backend, &a.data, &b.data, &mut got_t, m, threads);
                    for i in 0..m {
                        for j in 0..n {
                            assert_eq!(got_t[j * m + i], want[i * n + j], "{m}x{k}x{n} t={threads} ({i},{j})");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dot_i8_tile_matches_scalar_reduction() {
        // the fixed-width tile is an exact integer reduction: every
        // length class (full tiles, lane remainders, empty) agrees with
        // the naive scalar loop
        let mut rng = Rng::new(21);
        for k in [0usize, 1, 3, 15, 16, 17, 31, 32, 64, 100, 333] {
            let a: Vec<i8> = (0..k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let want: i32 = a.iter().zip(&b).map(|(&x, &w)| x as i32 * w as i32).sum();
            assert_eq!(dot_i8_i32(&a, &b), want, "k={k}");
        }
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[37, 19], 1.0, &mut rng);
        let got = gram(&a);
        let want = matmul(&a.t(), &a);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn gram_matches_ref_at_scale() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[129, 65], 1.0, &mut rng);
        let want = gram_ref(&a);
        for threads in [1usize, 2, 8] {
            let got = gram_with_threads(&a, threads);
            assert!(got.max_abs_diff(&want) < 1e-3, "t={threads}");
        }
    }

    #[test]
    fn gram_accumulate_streams() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[40, 16], 1.0, &mut rng);
        let full = gram(&a);
        let mut h = Tensor::zeros(&[16, 16]);
        for i in 0..4 {
            let chunk =
                Tensor::new(a.data[i * 10 * 16..(i + 1) * 10 * 16].to_vec(), vec![10, 16]);
            gram_accumulate(&mut h, &chunk);
        }
        assert!(h.max_abs_diff(&full) < 1e-3);
    }

    #[test]
    fn gram_accumulate_stays_symmetric() {
        let mut rng = Rng::new(3);
        let mut h = Tensor::zeros(&[33, 33]);
        for _ in 0..3 {
            let a = Tensor::randn(&[17, 33], 1.0, &mut rng);
            gram_accumulate(&mut h, &a);
        }
        for i in 0..33 {
            for j in 0..i {
                assert_eq!(h.data[i * 33 + j], h.data[j * 33 + i], "asymmetric at ({i},{j})");
            }
        }
    }

    #[test]
    fn gram_accumulate_rmsnorm_matches_two_step_bitwise() {
        use crate::model::rmsnorm_rows;
        let mut rng = Rng::new(9);
        // odd shapes straddle GRAM_ROW_BLOCK and the thread chunking
        for (m, n) in [(9usize, 5usize), (64, 16), (130, 33), (1, 6)] {
            let x = Tensor::randn(&[m, n], 2.0, &mut rng);
            let mut want = Tensor::zeros(&[n, n]);
            gram_accumulate_with_threads(&mut want, &rmsnorm_rows(&x), 1);
            for threads in [1usize, 2, 8] {
                let mut got = Tensor::zeros(&[n, n]);
                gram_accumulate_rmsnorm_with_threads(&mut got, &x, threads);
                assert_eq!(got.data, want.data, "{m}x{n} t={threads}");
            }
            // streamed accumulation on top of prior content agrees too
            let mut got = Tensor::zeros(&[n, n]);
            gram_accumulate_rmsnorm_with_threads(&mut got, &x, 4);
            gram_accumulate_rmsnorm_with_threads(&mut got, &x, 4);
            let mut want2 = want.clone();
            gram_accumulate_with_threads(&mut want2, &rmsnorm_rows(&x), 1);
            assert_eq!(got.data, want2.data, "streamed {m}x{n}");
        }
    }

    #[test]
    fn gram_accumulate_flattens_leading_axes() {
        let mut rng = Rng::new(10);
        let x3 = Tensor::randn(&[2, 5, 8], 1.0, &mut rng);
        let x2 = x3.clone().reshape(&[10, 8]);
        let mut a = Tensor::zeros(&[8, 8]);
        let mut b = Tensor::zeros(&[8, 8]);
        gram_accumulate(&mut a, &x3);
        gram_accumulate(&mut b, &x2);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn dot_i8_i32_is_exact() {
        let mut rng = Rng::new(5);
        for k in [0usize, 1, 7, 64, 333] {
            let a: Vec<i8> = (0..k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
            let b: Vec<i8> = (0..k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
            let want: i64 = a.iter().zip(&b).map(|(&x, &w)| x as i64 * w as i64).sum();
            assert_eq!(dot_i8_i32(&a, &b) as i64, want, "k={k}");
        }
        // extremes don't overflow the per-element product
        assert_eq!(dot_i8_i32(&[-128; 4], &[127; 4]), -128 * 127 * 4);
    }

    #[test]
    fn dot_i8_grouped_folds_scales_per_group() {
        let mut rng = Rng::new(6);
        // odd k with a group that doesn't divide it (ragged last group)
        let (k, group) = (13usize, 5usize);
        let xq: Vec<i8> = (0..k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
        let wq: Vec<i8> = (0..k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
        let wscales = [0.25f32, 0.5, 0.125];
        let act = 0.75f32;
        let got = dot_i8_grouped(&xq, &wq, &wscales, group, act);
        let mut want = 0.0f32;
        for g in 0..3 {
            let i0 = g * group;
            let i1 = (i0 + group).min(k);
            let part: i32 = (i0..i1).map(|i| xq[i] as i32 * wq[i] as i32).sum();
            want += (act * wscales[g]) * part as f32;
        }
        assert_eq!(got, want, "fold must match the documented expression bitwise");
    }

    #[test]
    fn rows_matmul_keeps_leading_shape() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 5, 8], 1.0, &mut rng);
        let w = Tensor::randn(&[8, 3], 1.0, &mut rng);
        let y = rows_matmul(&x, &w);
        assert_eq!(y.shape, vec![2, 5, 3]);
    }
}
