//! Blocked matmul kernels for the offline (coordinator-side) hot paths:
//! rotation fusion (W ← RᵀW), Hessian accumulation (XᵀX) in GPTQ, and the
//! sensitivity sweeps. Cache-blocked with an i-k-j inner loop so the
//! innermost loop is a contiguous AXPY the compiler auto-vectorizes.

use super::Tensor;

const BLOCK: usize = 64;

/// C = A @ B for 2-D tensors (m,k) × (k,n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.rank(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {:?} @ {:?}", a.shape, b.shape);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(&a.data, &b.data, &mut c.data, m, k, n);
    c
}

/// C += A @ B on raw row-major slices.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = a[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// C = Aᵀ @ A (Gram / GPTQ Hessian accumulation), exploiting symmetry.
pub fn gram(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut c = Tensor::zeros(&[n, n]);
    for row in 0..m {
        let r = &a.data[row * n..(row + 1) * n];
        for i in 0..n {
            let ri = r[i];
            if ri == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in i..n {
                crow[j] += ri * r[j];
            }
        }
    }
    // mirror the upper triangle
    for i in 0..n {
        for j in 0..i {
            c.data[i * n + j] = c.data[j * n + i];
        }
    }
    c
}

/// Accumulate Aᵀ@A into an existing (n,n) Hessian (streamed batches).
pub fn gram_accumulate(h: &mut Tensor, a: &Tensor) {
    assert_eq!(a.rank(), 2);
    let n = a.shape[1];
    assert_eq!(h.shape, vec![n, n]);
    let m = a.shape[0];
    for row in 0..m {
        let r = &a.data[row * n..(row + 1) * n];
        for i in 0..n {
            let ri = r[i];
            if ri == 0.0 {
                continue;
            }
            let hrow = &mut h.data[i * n..(i + 1) * n];
            for j in 0..n {
                hrow[j] += ri * r[j];
            }
        }
    }
}

/// y = x @ W for a batch of rows (x: (m,k) flattened leading dims).
pub fn rows_matmul(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.as_2d();
    assert_eq!(w.rank(), 2);
    assert_eq!(w.shape[0], k);
    let n = w.shape[1];
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(&x.data, &w.data, &mut out.data, m, k, n);
    let mut shape = x.shape.clone();
    *shape.last_mut().unwrap() = n;
    out.reshape(&shape)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.data[i * k + kk] * b.data[kk * n + j];
                }
                c.data[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(0);
        for (m, k, n) in [(3, 4, 5), (65, 67, 63), (128, 128, 128), (1, 200, 1)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[37, 19], 1.0, &mut rng);
        let got = gram(&a);
        let want = matmul(&a.t(), &a);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn gram_accumulate_streams() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[40, 16], 1.0, &mut rng);
        let full = gram(&a);
        let mut h = Tensor::zeros(&[16, 16]);
        for i in 0..4 {
            let chunk =
                Tensor::new(a.data[i * 10 * 16..(i + 1) * 10 * 16].to_vec(), vec![10, 16]);
            gram_accumulate(&mut h, &chunk);
        }
        assert!(h.max_abs_diff(&full) < 1e-3);
    }

    #[test]
    fn rows_matmul_keeps_leading_shape() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 5, 8], 1.0, &mut rng);
        let w = Tensor::randn(&[8, 3], 1.0, &mut rng);
        let y = rows_matmul(&x, &w);
        assert_eq!(y.shape, vec![2, 5, 3]);
    }
}
