//! Dense linear algebra for GPTQ: Cholesky factorization, triangular
//! solves, and SPD inversion. GPTQ (Frantar et al. 2022) needs the upper
//! Cholesky factor of H⁻¹ where H = XᵀX + λI is the layer-input Hessian.
//! No LAPACK anywhere — everything is written out so the whole coordinator
//! stays dependency-free.

use super::Tensor;

/// Lower-triangular Cholesky factor L of SPD A = L·Lᵀ.
/// Returns None if A is not (numerically) positive definite.
pub fn cholesky(a: &Tensor) -> Option<Tensor> {
    assert_eq!(a.rank(), 2);
    let n = a.shape[0];
    assert_eq!(a.shape[1], n);
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.data[i * n + j] as f64;
            for k in 0..j {
                s -= l.data[i * n + k] as f64 * l.data[j * n + k] as f64;
            }
            if i == j {
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                l.data[i * n + j] = (s.sqrt()) as f32;
            } else {
                l.data[i * n + j] = (s / l.data[j * n + j] as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve L·y = b with L lower-triangular (forward substitution).
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.shape[0];
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.data[i * n + k] as f64 * y[k] as f64;
        }
        y[i] = (s / l.data[i * n + i] as f64) as f32;
    }
    y
}

/// Solve Lᵀ·x = y (backward substitution on the transpose of lower L).
pub fn solve_lower_t(l: &Tensor, y: &[f32]) -> Vec<f32> {
    let n = l.shape[0];
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in (i + 1)..n {
            s -= l.data[k * n + i] as f64 * x[k] as f64;
        }
        x[i] = (s / l.data[i * n + i] as f64) as f32;
    }
    x
}

/// SPD inverse via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &Tensor) -> Option<Tensor> {
    let n = a.shape[0];
    let l = cholesky(a)?;
    let mut inv = Tensor::zeros(&[n, n]);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.data[i * n + j] = x[i];
        }
        e[j] = 0.0;
    }
    Some(inv)
}

/// Upper Cholesky factor U of A (A = UᵀU); GPTQ uses chol(H⁻¹) upper.
pub fn cholesky_upper(a: &Tensor) -> Option<Tensor> {
    // A = L Lᵀ ⇒ with U = Lᵀ, A = Uᵀ U.
    cholesky(a).map(|l| l.t())
}

/// Add λ·mean(diag)·I damping in place (GPTQ percdamp).
pub fn dampen(h: &mut Tensor, lambda: f32) {
    let n = h.shape[0];
    let mean_diag = (0..n).map(|i| h.data[i * n + i]).sum::<f32>() / n as f32;
    let eps = lambda * mean_diag.max(1e-8);
    for i in 0..n {
        h.data[i * n + i] += eps;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::{gram, matmul};
    use crate::util::Rng;

    fn random_spd(n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let a = Tensor::randn(&[n + 4, n], 1.0, &mut rng);
        let mut h = gram(&a);
        dampen(&mut h, 0.01);
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(16, 0);
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &l.t());
        assert!(rec.max_abs_diff(&a) < 1e-2 * a.max_abs().max(1.0));
    }

    #[test]
    fn solves_invert() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(2);
        let b: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // A x should equal b
        let ax = matmul(&a, &Tensor::new(x, vec![12, 1]));
        for (got, want) in ax.data.iter().zip(&b) {
            assert!((got - want).abs() < 1e-2, "{got} vs {want}");
        }
    }

    #[test]
    fn spd_inverse_identity() {
        let a = random_spd(10, 3);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Tensor::eye(10)) < 1e-2);
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = Tensor::eye(4);
        a.data[0] = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn upper_factor() {
        let a = random_spd(8, 4);
        let u = cholesky_upper(&a).unwrap();
        let rec = matmul(&u.t(), &u);
        assert!(rec.max_abs_diff(&a) < 1e-2 * a.max_abs().max(1.0));
        // strictly upper-triangular below diagonal is zero
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(u.data[i * 8 + j], 0.0);
            }
        }
    }
}
