//! Hadamard machinery on the coordinator side: explicit matrices for
//! fusion (R1/R2 candidates, QuaRot baselines) and the in-place FWHT for
//! metric computations. Mirrors `python/compile/kernels/hadamard.py`.
//!
//! `fwht_rows` is batch-parallel: rows are independent, so they partition
//! across scoped threads with per-row butterflies untouched — results are
//! bitwise identical at every thread count. The sequential seed kernel
//! survives as [`fwht_rows_ref`] (bench baseline).

use super::{matmul::matmul, Tensor};
use crate::util::par::{self, num_threads};
use crate::util::Rng;

/// Normalized Sylvester Hadamard matrix H/√n (n must be a power of two).
pub fn hadamard_matrix(n: usize) -> Tensor {
    assert!(n.is_power_of_two(), "hadamard dim {n} not a power of two");
    let mut h = vec![1.0f32];
    let mut m = 1;
    while m < n {
        let mut next = vec![0.0f32; 4 * m * m];
        for i in 0..m {
            for j in 0..m {
                let v = h[i * m + j];
                next[i * 2 * m + j] = v;
                next[i * 2 * m + j + m] = v;
                next[(i + m) * 2 * m + j] = v;
                next[(i + m) * 2 * m + j + m] = -v;
            }
        }
        h = next;
        m *= 2;
    }
    let s = 1.0 / (n as f32).sqrt();
    Tensor::new(h.into_iter().map(|v| v * s).collect(), vec![n, n])
}

/// H·diag(signs) from a pre-drawn ±1 vector. Splitting the draw from the
/// construction lets callers (QuaRot) consume their RNG sequentially —
/// keeping rotations bit-identical to the all-sequential path — while the
/// O(n²) column scaling runs row-parallel.
pub fn hadamard_from_signs(n: usize, signs: &[f32]) -> Tensor {
    assert_eq!(signs.len(), n, "sign vector length");
    let mut h = hadamard_matrix(n);
    par::par_row_chunks_mut(&mut h.data, n, 16, num_threads(), |_r0, chunk| {
        for row in chunk.chunks_exact_mut(n) {
            for (v, s) in row.iter_mut().zip(signs) {
                *v *= s;
            }
        }
    });
    h
}

/// QuaRot-style random Hadamard rotation: H·diag(±1).
pub fn random_hadamard(n: usize, rng: &mut Rng) -> Tensor {
    let signs: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
    hadamard_from_signs(n, &signs)
}

/// In-place FWHT along the last axis of each row, normalized by 1/√n.
/// Rows run in parallel; per-row math is identical to [`fwht_rows_ref`].
pub fn fwht_rows(x: &mut Tensor) {
    fwht_rows_with_threads(x, num_threads());
}

/// [`fwht_rows`] with an explicit thread budget (tests / tuning).
pub fn fwht_rows_with_threads(x: &mut Tensor, threads: usize) {
    let (_rows, n) = x.as_2d();
    assert!(n.is_power_of_two());
    let norm = 1.0 / (n as f32).sqrt();
    par::par_row_chunks_mut(&mut x.data, n, 8, threads, |_r0, chunk| {
        for row in chunk.chunks_exact_mut(n) {
            fwht_row(row, norm);
        }
    });
}

/// One row's butterfly network + normalization.
#[inline]
fn fwht_row(row: &mut [f32], norm: f32) {
    let n = row.len();
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = row[j];
                let b = row[j + h];
                row[j] = a + b;
                row[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    for v in row.iter_mut() {
        *v *= norm;
    }
}

/// Scalar reference FWHT (original sequential kernel; bench baseline).
pub fn fwht_rows_ref(x: &mut Tensor) {
    let (rows, n) = x.as_2d();
    assert!(n.is_power_of_two());
    let norm = 1.0 / (n as f32).sqrt();
    for r in 0..rows {
        fwht_row(&mut x.data[r * n..(r + 1) * n], norm);
    }
}

/// max |RᵀR − I| — orthogonality check used by tests and the kurtail
/// driver's convergence guard.
pub fn orthogonality_error(r: &Tensor) -> f32 {
    let n = r.shape[0];
    let g = matmul(&r.t(), r);
    let mut err = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            err = err.max((g.data[i * n + j] - want).abs());
        }
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::rows_matmul;

    #[test]
    fn hadamard_is_orthogonal() {
        for n in [2, 4, 16, 64, 128] {
            assert!(orthogonality_error(&hadamard_matrix(n)) < 1e-5, "n={n}");
        }
    }

    #[test]
    fn random_hadamard_is_orthogonal() {
        let mut rng = Rng::new(0);
        for n in [8, 32, 256] {
            assert!(orthogonality_error(&random_hadamard(n, &mut rng)) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn from_signs_matches_random_hadamard_stream() {
        // drawing the signs first then constructing must equal the
        // one-shot constructor on the same RNG stream
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let h1 = random_hadamard(64, &mut a);
        let signs: Vec<f32> = (0..64).map(|_| b.sign()).collect();
        let h2 = hadamard_from_signs(64, &signs);
        assert_eq!(h1.data, h2.data);
    }

    #[test]
    fn fwht_matches_matrix() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[7, 64], 1.0, &mut rng);
        let want = rows_matmul(&x, &hadamard_matrix(64));
        let mut got = x.clone();
        fwht_rows(&mut got);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn fwht_parallel_matches_ref_exactly() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[33, 128], 1.0, &mut rng);
        let mut want = x.clone();
        fwht_rows_ref(&mut want);
        for threads in [1usize, 2, 8] {
            let mut got = x.clone();
            fwht_rows_with_threads(&mut got, threads);
            assert_eq!(got.data, want.data, "t={threads}");
        }
    }

    #[test]
    fn fwht_involution() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[5, 32], 1.0, &mut rng);
        let mut y = x.clone();
        fwht_rows(&mut y);
        fwht_rows(&mut y);
        assert!(y.max_abs_diff(&x) < 1e-4);
    }

    #[test]
    fn fwht_flattens_onehot() {
        let mut x = Tensor::zeros(&[1, 64]);
        x.data[17] = 8.0;
        fwht_rows(&mut x);
        for v in &x.data {
            assert!((v.abs() - 1.0).abs() < 1e-5);
        }
    }
}
