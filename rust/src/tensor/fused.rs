//! Fused rotate→consume kernels.
//!
//! The analysis/eval paths repeatedly compute `rows_matmul(x, R)` only to
//! immediately reduce the rotated rows (absmax, quantization MSE, fake
//! quant) and throw them away — materializing a full rotated copy of a
//! multi-hundred-MiB activation pool per rotation. The kernels here
//! rotate a bounded row-chunk at a time into a thread-local panel buffer
//! (reusing the packed-B layout from `matmul`), consume it in place, and
//! move on: peak extra memory is `FUSE_CHUNK_ROWS × d` floats per thread
//! instead of a whole tensor, and the chunks run in parallel.

use super::matmul::{matmul_packed_chunk, pack_b};
use super::Tensor;
use crate::util::par::{self, num_threads};

/// Rows rotated per thread-local buffer refill.
pub(crate) const FUSE_CHUNK_ROWS: usize = 64;

/// Per-row max |x·R| without materializing the rotated tensor.
/// `rot = None` is the vanilla (identity) path.
pub fn rotate_row_absmax(x: &Tensor, rot: Option<&Tensor>) -> Vec<f32> {
    let (r, _c) = x.as_2d();
    let n_chunks = (r + FUSE_CHUNK_ROWS - 1) / FUSE_CHUNK_ROWS;
    // one FUSE_CHUNK_ROWS-wide output row per chunk: every chunk except
    // the ragged tail is full, so the valid values are the prefix [0, r)
    let mut padded = vec![0.0f32; n_chunks * FUSE_CHUNK_ROWS];
    map_rotated_chunks(x, rot, &mut padded, FUSE_CHUNK_ROWS, |_r0, data, rows, out| {
        let c = data.len() / rows;
        for (i, o) in out[..rows].iter_mut().enumerate() {
            *o = absmax(&data[i * c..(i + 1) * c]);
        }
    });
    padded.truncate(r);
    padded
}

#[inline]
fn absmax(row: &[f32]) -> f32 {
    row.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
}

/// Run `consume(first_row, rotated_rows, n_rows)` over fixed-size chunks
/// of `x·R` (or of `x` itself when `rot` is `None`), in parallel, storing
/// per-chunk results in `out` (one row of `out_width` elements per input
/// chunk, chunk b covering input rows `[b·FUSE_CHUNK_ROWS, …)`).
///
/// The chunk grid is fixed — independent of the thread count — so any
/// reduction the caller performs over `out` in chunk order is
/// deterministic across thread counts.
pub fn map_rotated_chunks<T, F>(x: &Tensor, rot: Option<&Tensor>, out: &mut [T], out_width: usize, consume: F)
where
    T: Send,
    F: Fn(usize, &[f32], usize, &mut [T]) + Sync,
{
    let (r, c) = x.as_2d();
    let n_chunks = (r + FUSE_CHUNK_ROWS - 1) / FUSE_CHUNK_ROWS;
    assert_eq!(out.len(), n_chunks * out_width, "out must hold one row per chunk");
    if r == 0 || c == 0 || out.is_empty() {
        return;
    }
    let threads = num_threads();
    if let Some(rm) = rot {
        assert_eq!(rm.shape, vec![c, c], "rotation must be ({c},{c})");
    }
    let packed = rot.map(|rm| pack_b(&rm.data, c, c, threads));
    // one rotate buffer per *worker* (not per par chunk): under the
    // work-stealing backend a worker sweeps many fine chunks, and the
    // buffer rides along instead of being re-allocated per chunk
    let mut bufs: Vec<Vec<f32>> = (0..threads.max(1)).map(|_| Vec::new()).collect();
    par::par_row_chunks_scratch_mut(out, out_width, 1, threads, &mut bufs, |b0, ochunk, buf| {
        if packed.is_some() && buf.len() < FUSE_CHUNK_ROWS * c {
            buf.resize(FUSE_CHUNK_ROWS * c, 0.0);
        }
        for (bi, orow) in ochunk.chunks_exact_mut(out_width).enumerate() {
            let r0 = (b0 + bi) * FUSE_CHUNK_ROWS;
            let rows = FUSE_CHUNK_ROWS.min(r - r0);
            match &packed {
                Some(p) => {
                    let b = &mut buf[..rows * c];
                    b.fill(0.0);
                    matmul_packed_chunk(&x.data[r0 * c..(r0 + rows) * c], p, b, rows, c, c);
                    consume(r0, &buf[..rows * c], rows, orow);
                }
                None => consume(r0, &x.data[r0 * c..(r0 + rows) * c], rows, orow),
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::random_hadamard;
    use crate::tensor::matmul::rows_matmul;
    use crate::tensor::stats::row_absmax;
    use crate::util::Rng;

    #[test]
    fn fused_absmax_matches_materialized() {
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[219, 64], 1.0, &mut rng);
        let r = random_hadamard(64, &mut rng);
        let want = row_absmax(&rows_matmul(&x, &r));
        let got = rotate_row_absmax(&x, Some(&r));
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
        // identity path
        let got_id = rotate_row_absmax(&x, None);
        let want_id = row_absmax(&x);
        assert_eq!(got_id, want_id);
    }

    #[test]
    fn map_rotated_chunks_covers_all_rows() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[150, 32], 1.0, &mut rng); // 3 chunks: 64+64+22
        let n_chunks = (150 + FUSE_CHUNK_ROWS - 1) / FUSE_CHUNK_ROWS;
        let mut sums = vec![0.0f64; n_chunks];
        map_rotated_chunks(&x, None, &mut sums, 1, |_r0, rows, _n, out| {
            out[0] = rows.iter().map(|&v| v as f64).sum();
        });
        let total: f64 = sums.iter().sum();
        let want: f64 = x.data.iter().map(|&v| v as f64).sum();
        assert!((total - want).abs() < 1e-3);
    }

    #[test]
    fn map_rotated_chunks_rotates() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[70, 16], 1.0, &mut rng);
        let r = random_hadamard(16, &mut rng);
        let xr = rows_matmul(&x, &r);
        let n_chunks = 2;
        let mut maxima = vec![0.0f32; n_chunks];
        map_rotated_chunks(&x, Some(&r), &mut maxima, 1, |_r0, rows, _n, out| {
            out[0] = rows.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        });
        let want = xr.max_abs();
        let got = maxima.iter().fold(0.0f32, |a, &v| a.max(v));
        assert!((got - want).abs() < 1e-4);
    }
}
