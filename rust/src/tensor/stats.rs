//! Statistics used throughout the pipeline: central moments, kurtosis,
//! quantiles — the Rust mirrors of `python/compile/kernels/ref.py` (the
//! pytest goldens pin both sides to the same semantics).

use super::Tensor;
use crate::util::par::{self, num_threads};

/// Per-row kurtosis κ = m4/m2² over the last axis (κ_uniform = 1.8,
/// κ_normal = 3, κ_laplace = 6). Rows are independent, so the reduction
/// runs row-parallel (deterministic: per-row math is untouched).
pub fn kurtosis_rows(x: &Tensor) -> Vec<f32> {
    let (r, c) = x.as_2d();
    let mut out = vec![0.0f32; r];
    if r == 0 || c == 0 {
        return out;
    }
    par::par_row_chunks_mut(&mut out, 1, 64, num_threads(), |r0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = kurtosis(&x.data[(r0 + i) * c..(r0 + i + 1) * c]);
        }
    });
    out
}

pub fn kurtosis(xs: &[f32]) -> f32 {
    let n = xs.len() as f32;
    let mu = xs.iter().sum::<f32>() / n;
    let mut m2 = 0.0f64;
    let mut m4 = 0.0f64;
    for &x in xs {
        let c = (x - mu) as f64;
        let c2 = c * c;
        m2 += c2;
        m4 += c2 * c2;
    }
    m2 /= n as f64;
    m4 /= n as f64;
    (m4 / (m2 * m2).max(1e-12)) as f32
}

pub const KURTOSIS_UNIFORM: f32 = 1.8;

/// Mean per-row |κ − κ_u| — the KurTail objective, host-side.
pub fn kurtail_loss(x: &Tensor) -> f32 {
    let ks = kurtosis_rows(x);
    ks.iter().map(|k| (k - KURTOSIS_UNIFORM).abs()).sum::<f32>() / ks.len() as f32
}

/// Linear-interpolated quantile (matches numpy / ref.py semantics).
pub fn quantile(xs: &[f32], q: f32) -> f32 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f32;
    let lo = pos.floor() as usize;
    let hi = (lo + 1).min(v.len() - 1);
    let frac = pos - lo as f32;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Per-row max |x| (the Table-1 per-token max statistic), row-parallel.
pub fn row_absmax(x: &Tensor) -> Vec<f32> {
    let (r, c) = x.as_2d();
    let mut out = vec![0.0f32; r];
    if r == 0 || c == 0 {
        return out;
    }
    par::par_row_chunks_mut(&mut out, 1, 128, num_threads(), |r0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = x.data[(r0 + i) * c..(r0 + i + 1) * c]
                .iter()
                .fold(0.0f32, |a, &v| a.max(v.abs()));
        }
    });
    out
}

pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    let n = xs.len() as f32;
    let mu = xs.iter().sum::<f32>() / n;
    let var = xs.iter().map(|x| (x - mu).powi(2)).sum::<f32>() / n;
    (mu, var.sqrt())
}

/// Histogram over [lo, hi] with `bins` buckets (Fig. 2 distribution dumps).
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &x in xs {
        if x.is_finite() && x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kurtosis_known() {
        let mut rng = Rng::new(0);
        let n = 100_000;
        let gauss: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let unif: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let lap: Vec<f32> = (0..n).map(|_| rng.laplace(1.0)).collect();
        assert!((kurtosis(&gauss) - 3.0).abs() < 0.15);
        assert!((kurtosis(&unif) - 1.8).abs() < 0.05);
        assert!((kurtosis(&lap) - 6.0).abs() < 0.6);
    }

    #[test]
    fn quantile_interp() {
        let xs = vec![0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.0).abs() < 1e-6);
        assert!((quantile(&xs, 0.9) - 3.6).abs() < 1e-6);
    }

    #[test]
    fn kurtail_loss_prefers_uniform() {
        let mut rng = Rng::new(1);
        let unif = Tensor::new((0..64 * 512).map(|_| rng.range(-1.0, 1.0)).collect(), vec![64, 512]);
        let lap = Tensor::new((0..64 * 512).map(|_| rng.laplace(1.0)).collect(), vec![64, 512]);
        assert!(kurtail_loss(&unif) < 0.2);
        assert!(kurtail_loss(&lap) > 2.0);
    }

    #[test]
    fn histogram_counts() {
        let xs = vec![0.1, 0.2, 0.9, 0.95, -5.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }
}
