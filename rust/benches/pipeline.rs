//! End-to-end pipeline stage benchmarks on the tiny config: training
//! step, layer-wise capture, full method pipelines, evaluation calls.
//! The table the §Perf pass optimizes against.

use std::sync::Arc;

use kurtail::config::{Method, PipelineConfig, WeightQuantizer};
use kurtail::eval::perplexity;
use kurtail::model::capture_stream;
use kurtail::pipeline::Pipeline;
use kurtail::rotation::fold_norms;
use kurtail::runtime::Runtime;
use kurtail::util::bench::Bench;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("SKIP pipeline bench: {e:#}");
            return;
        }
    };
    let mut b = Bench::quick();
    let pipe = Pipeline::new(rt, "tiny", 0, true, false).expect("pipeline");

    // layer-wise capture of one batch
    let mut folded = pipe.fp_params.clone();
    fold_norms(&mut folded);
    let batches = pipe.bundle.calib_batches(kurtail::calib::CorpusKind::Wiki, 4, 4, 0);
    b.run("capture_stream(1 batch, all layers)", || {
        capture_stream(&pipe.rt, &folded, &batches[..1], |_| Ok(())).unwrap()
    });

    // full method pipelines (quantize only; eval separate)
    for method in [Method::GptqOnly, Method::QuaRot, Method::KurTail] {
        let mut cfg = PipelineConfig::new("tiny", method);
        cfg.weight_quantizer = WeightQuantizer::Gptq;
        cfg.calib.n_samples = 32;
        cfg.calib.iters = 10;
        b.run(&format!("pipeline_quantize/{}", method.label()), || {
            pipe.quantize(&cfg).unwrap()
        });
    }

    // evaluation calls
    let fp = pipe.quantize(&PipelineConfig::new("tiny", Method::Fp16)).unwrap().0;
    b.run("perplexity_fp(4 batches)", || perplexity(&pipe.rt, &fp, &pipe.bundle.test, 4).unwrap());
    let mut cfg = PipelineConfig::new("tiny", Method::KurTail);
    cfg.calib.n_samples = 32;
    cfg.calib.iters = 10;
    let kt = pipe.quantize(&cfg).unwrap().0;
    b.run("perplexity_quant(4 batches)", || {
        perplexity(&pipe.rt, &kt, &pipe.bundle.test, 4).unwrap()
    });
}
