//! GPTQ benchmark: per-layer weight quantization cost vs RTN, including
//! the Hessian preparation (Cholesky of H⁻¹). This is the dominant
//! offline cost of every GPTQ table row.

use kurtail::config::QuantScheme;
use kurtail::quant::{gptq_quantize, rtn_quantize};
use kurtail::quant::gptq::hessian_error;
use kurtail::tensor::matmul::gram;
use kurtail::tensor::Tensor;
use kurtail::util::bench::Bench;
use kurtail::util::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0);
    let s = QuantScheme::weight4();

    for (k, n) in [(64usize, 64usize), (128, 128), (256, 256), (256, 512)] {
        let w = Tensor::randn(&[k, n], 0.3, &mut rng);
        let x = Tensor::randn(&[512, k], 1.0, &mut rng);
        let h = gram(&x);
        b.run(&format!("gptq_{k}x{n}"), || gptq_quantize(&w, &h, &s));
        b.run(&format!("rtn_{k}x{n}"), || rtn_quantize(&w, &s));
        // record the quality gap alongside the speed gap
        let eg = hessian_error(&w, &gptq_quantize(&w, &h, &s), &h);
        let er = hessian_error(&w, &rtn_quantize(&w, &s), &h);
        println!("  quality: hessian-error gptq {eg:.5} vs rtn {er:.5} (ratio {:.2})", er / eg);
    }
}
