//! Rotation-learning cost benchmark (paper §3 "Training Cost"):
//! per-iteration cost of KurTail's kurtosis Cayley-Adam step vs
//! SpinQuant's end-to-end CE step, at matched model size. The asymmetry
//! (layer-wise data vs full-model autograd) is the paper's 1-GPU-vs-4×H100
//! argument, measured here as step wall-clock.

use kurtail::model::{Params, RowReservoir};
use kurtail::runtime::{Runtime, Value};
use kurtail::tensor::{IntTensor, Tensor};
use kurtail::util::bench::Bench;
use kurtail::util::Rng;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP rotation_learning bench: {e:#}");
            return;
        }
    };
    let mut b = Bench::new();
    let mut rng = Rng::new(0);

    // KurTail step at the dims of each config
    for d in [64usize, 128, 256] {
        let art = rt.load(&format!("kurtail_step_d{d}")).expect("load");
        let rows = rt.manifest.kurtail_rows;
        let mut pool = RowReservoir::new(d, rows, 0);
        pool.offer(&Tensor::randn(&[rows, d], 1.0, &mut rng));
        let x = pool.sample(rows);
        let r = Tensor::eye(d);
        let m = Tensor::zeros(&[d, d]);
        b.run(&format!("kurtail_step_d{d}"), || {
            art.run(&[
                Value::F32(r.clone()),
                Value::F32(m.clone()),
                Value::from(0.0f32),
                Value::F32(x.clone()),
                Value::from(0.05f32),
                Value::from(1.0f32),
            ])
            .unwrap()
        });
    }

    // SpinQuant step per config (full model + backprop inside the graph)
    for cfg in ["tiny", "small", "base"] {
        let Ok(meta) = rt.manifest.config(cfg) else { continue };
        let meta = meta.clone();
        let Ok(art) = rt.load(&format!("spinquant_step_{cfg}")) else { continue };
        let params = Params::init(&meta, &mut rng);
        let d = meta.d_model;
        let tokens = IntTensor::new(
            (0..meta.spin_batch * meta.seq_len).map(|i| (i % 250) as i32).collect(),
            vec![meta.spin_batch, meta.seq_len],
        );
        let mut inputs = params.as_values();
        inputs.push(Value::F32(Tensor::eye(d)));
        inputs.push(Value::F32(Tensor::zeros(&[d, d])));
        inputs.push(Value::from(0.0f32));
        inputs.push(Value::I32(tokens));
        inputs.push(Value::from(1e-3f32));
        inputs.push(Value::from(1.0f32));
        b.run(&format!("spinquant_step_{cfg}"), || art.run(&inputs).unwrap());
    }

    println!("\nratio of interest: spinquant_step_<cfg> / kurtail_step_d<d_model(cfg)>");
}
