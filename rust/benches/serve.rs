//! Serving-engine benchmark: continuous-batching INT4 decode vs the
//! dense-f32 single-lane path, on a synthetic llama config sized so the
//! weight traversal dominates (d_head 64 → the 4-bit KV layout shows its
//! full ≥6× memory win). No artifacts needed — the engine is native.
//!
//! Every lane count runs the quantized engine five ways:
//!
//! * integer-accumulator GEMM, arena + panel cache + fused column-major
//!   epilogues + work-stealing runtime (`tok_s` — the default serving
//!   path),
//! * the same arena profile with the PR-4 **serial-flip** epilogue
//!   (`ServeConfig::fused_epilogue = Some(false)`):
//!   `serial_epilogue_tok_s`, and `epilogue_fused_speedup = tok_s /
//!   serial_epilogue_tok_s` isolates the fused-epilogue win (gated by
//!   `scripts/check_bench.sh` at lanes = 16),
//! * the same arena+fused profile on the **static** scoped-thread
//!   runtime (`ServeConfig::par_backend = Some(Static)`):
//!   `static_par_tok_s`, and `steal_speedup = tok_s / static_par_tok_s`
//!   isolates the work-stealing win on the mixed serving batch (the
//!   skewed-kernel steal case lives in `benches/kernels.rs`),
//! * integer GEMM on the PR-3 fresh-alloc profile
//!   (`ServeConfig::arena = Some(false)`, `panel_cache = Some(0)`):
//!   `legacy_alloc_tok_s`, and `arena_speedup = tok_s /
//!   legacy_alloc_tok_s` isolates the arena + panel win,
//! * f32 dequant GEMM on the same PR-3 profile (`f32_dequant_tok_s`):
//!   `int_gemm_speedup = legacy_alloc_tok_s / f32_dequant_tok_s` keeps
//!   the PR-3 definition of the INT4×INT4 headline — both of its sides
//!   on the fresh-alloc path — so the committed baseline floor stays
//!   comparable (`scripts/check_bench.sh` gates the speedups; each A/B
//!   isolates one knob so one knob's gain can't mask or fake another's
//!   regression),
//! * the default profile with observability disabled
//!   (`ServeConfig::obs = Some(false)`): `obs_off_tok_s`, and
//!   `obs_overhead = obs_off_tok_s / tok_s − 1` measures what the
//!   telemetry layer (clock reads + relaxed atomic records) costs;
//!   `scripts/check_bench.sh` caps it at 2% at lanes = 16.
//!
//! Each lane count then runs an **open-loop Poisson load** through the
//! daemon host (`spawn_host`, no socket in the path): seeded
//! exponential interarrivals at ~1.5× the measured closed-loop
//! capacity, one waiter thread per request, a bounded admission queue
//! (`queue_cap = 2 × lanes`) shedding the overload. Recorded per lane
//! count: `offered_req_s`, `sustained_req_s`, `p50_ttft_ms` /
//! `p99_ttft_ms` (time from submit to first streamed token) and
//! `shed_rate` (`scripts/check_bench.sh` gates `p99_ttft_ms` at
//! lanes = 16 as a *ceiling* — latency regressions fail, lower is
//! better).
//!
//! Each lane count also runs a **mixed-priority overload stage**: a
//! same-instant flood of low-class requests (4× the lane count against
//! a `queue_cap = 2 × lanes` admission queue) plus a small high-class
//! trickle arriving after the queue has filled. High-class arrivals
//! outrank every queued low-class request (evicting the newest queued
//! low request when the queue is full), so the high class should see
//! near-single-request TTFT while the low class eats the queueing
//! delay. Recorded: `hi_pri_p99_ttft_ms` (gated at lanes = 16 as a
//! *ceiling* by `scripts/check_bench.sh`) and `fairness_ratio`
//! (low-class p99 TTFT over high-class p99 TTFT — gated as a *floor*:
//! under overload the ratio collapsing toward 1 means priority
//! admission stopped working).
//!
//! Each lane count also runs a **shared-prefix stage**: 16 requests
//! over one 48-token shared system prompt (+ distinct 8-token
//! suffixes), the donor prefilled first so the rest attach its
//! registered blocks at admission. Recorded: `prefix_hit_ratio`
//! (shared / total prompt tokens — gated as a floor at lanes = 16),
//! `kv_bytes_per_token_shared` (effective bytes per logical token with
//! sharing) and `admission_p99_ms` (queue-wait p99 under the burst).
//!
//! At lanes = 16 only, a **KV-pressure stage** runs a low-class flood
//! (2× the lane count) through the daemon host while the deterministic
//! `kv_pressure` fault withholds half the block pool, then trickles in
//! a high-class tenant once every effective block is committed. Each
//! high arrival preempts the newest low lane (snapshot → release →
//! requeue at class front); preempted streams pause and later resume
//! via recompute, so with an unbounded queue every offered request
//! should still complete. Recorded: `completed_under_pressure_ratio`
//! (completions / offered — gated as a *floor* by
//! `scripts/check_bench.sh`; a drop below the floor means degradation
//! stopped being graceful and streams were dropped, not paused), plus
//! ungated `pressure_preempted` / `pressure_resumed` /
//! `pressure_recompute_tokens` context counters.
//!
//! Writes `BENCH_serve.json` (path override: `KURTAIL_BENCH_SERVE_JSON`)
//! with tokens/sec at 1/4/16 concurrent sequences and KV bytes/token for
//! the paged 4-bit pool vs the dense f32 cache. `scripts/bench.sh`
//! drops it at the repo root, next to `BENCH_kernels.json`.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use kurtail::config::{KvQuant, QuantScheme};
use kurtail::model::Params;
use kurtail::runtime::{ConfigMeta, ParamSpec};
use kurtail::serve::daemon::fault::FaultSpec;
use kurtail::serve::daemon::{spawn_host, Event, HostConfig, SubmitReq};
use kurtail::serve::{
    Engine, ParBackend, Priority, ServeConfig, ServeModel, ServeQuantSpec, TenantPolicy,
};
use kurtail::tensor::hadamard::random_hadamard;
use kurtail::util::json::{arr, num, obj, s as js, Json};
use kurtail::util::par::num_threads;
use kurtail::util::Rng;

const LANES: [usize; 3] = [1, 4, 16];
const REQUESTS: usize = 16;
const PROMPT_TOKENS: usize = 8;
const NEW_TOKENS: usize = 48;

/// Synthetic serving config: llama arch, d=256, 4 heads × d_head 64.
fn bench_meta() -> ConfigMeta {
    let (l, d, ff, v, h) = (4usize, 256usize, 512usize, 256usize, 4usize);
    let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.into(), shape };
    ConfigMeta {
        name: "servebench".into(),
        vocab: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_head: d / h,
        d_ff: ff,
        seq_len: 128,
        arch: "llama".into(),
        n_experts: 1,
        top_k: 1,
        train_batch: 1,
        eval_batch: 1,
        cap_batch: 1,
        decode_batch: 1,
        spin_batch: 1,
        param_specs: vec![
            spec("embed", vec![v, d]),
            spec("ln1", vec![l, d]),
            spec("wq", vec![l, d, d]),
            spec("wk", vec![l, d, d]),
            spec("wv", vec![l, d, d]),
            spec("wo", vec![l, d, d]),
            spec("ln2", vec![l, d]),
            spec("wg", vec![l, d, ff]),
            spec("wu", vec![l, d, ff]),
            spec("wd", vec![l, ff, d]),
            spec("lnf", vec![d]),
            spec("head", vec![v, d]),
        ],
    }
}

fn submit_all(eng: &mut Engine, requests: usize) {
    for i in 0..requests {
        let prompt: Vec<i32> = (0..PROMPT_TOKENS).map(|t| ((i * 31 + t * 7) % 256) as i32).collect();
        eng.submit_tokens(prompt, NEW_TOKENS, 0.0, 0xC0FFEE + i as u64).expect("submit");
    }
}

/// One timed engine run; returns (wall seconds, total tokens processed).
/// Engine construction (weight packing, panel build, arena sizing) sits
/// outside the timed region — it is per-deployment, not per-request.
#[allow(clippy::too_many_arguments)]
fn timed_run_cfg(
    model: &ServeModel,
    kv: KvQuant,
    lanes: usize,
    requests: usize,
    int_gemm: Option<bool>,
    arena: Option<bool>,
    panel_cache: Option<usize>,
    fused_epilogue: Option<bool>,
    par_backend: Option<ParBackend>,
    obs: Option<bool>,
) -> (f64, usize, Engine) {
    let cfg = ServeConfig {
        max_lanes: lanes,
        kv_quant: kv,
        int_gemm,
        arena,
        panel_cache,
        fused_epilogue,
        par_backend,
        obs,
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(model.clone(), &cfg).expect("engine");
    submit_all(&mut eng, requests);
    let t0 = Instant::now();
    let done = eng.run().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    (wall, tokens, eng)
}

fn timed_run(
    model: &ServeModel,
    kv: KvQuant,
    lanes: usize,
    requests: usize,
    int_gemm: Option<bool>,
    arena: Option<bool>,
    panel_cache: Option<usize>,
) -> (f64, usize, Engine) {
    timed_run_cfg(model, kv, lanes, requests, int_gemm, arena, panel_cache, None, None, None)
}

/// Open-loop Poisson load through the daemon host at ~1.5× the measured
/// closed-loop capacity. Returns the serving-latency metrics merged
/// into the lane's run row.
fn poisson_load(model: &ServeModel, lanes: usize, tok_s: f64) -> Vec<(&'static str, Json)> {
    const N_REQUESTS: usize = 48;
    let cfg = ServeConfig {
        max_lanes: lanes,
        kv_quant: KvQuant::Asym4,
        int_gemm: Some(true),
        arena: Some(true),
        fused_epilogue: Some(true),
        par_backend: Some(ParBackend::Steal),
        queue_cap: 2 * lanes,
        ..ServeConfig::default()
    };
    let eng = Engine::new(model.clone(), &cfg).expect("engine");
    let (host, handle) = spawn_host(eng, HostConfig::default());
    // a request is PROMPT+NEW tokens of work, so closed-loop capacity in
    // req/s is tok_s over that; offer 1.5× to force queueing + shedding
    let capacity_req_s = tok_s / (PROMPT_TOKENS + NEW_TOKENS) as f64;
    let offered_req_s = 1.5 * capacity_req_s;
    let mut gaps = Rng::new(0xA11CE);
    let t_start = Instant::now();
    let mut workers = Vec::with_capacity(N_REQUESTS);
    for i in 0..N_REQUESTS {
        // exponential interarrival: -ln(1-u)/λ, u ∈ [0,1)
        let gap = -(1.0 - gaps.uniform() as f64).ln() / offered_req_s;
        thread::sleep(Duration::from_secs_f64(gap));
        let host = host.clone();
        workers.push(thread::spawn(move || {
            let prompt: Vec<i32> =
                (0..PROMPT_TOKENS).map(|t| ((i * 31 + t * 7) % 256) as i32).collect();
            let (tx, rx) = mpsc::channel();
            let t0 = Instant::now();
            let req = SubmitReq {
                tokens: prompt,
                n_tokens: NEW_TOKENS,
                temp: 0.0,
                seed: 0xC0FFEE + i as u64,
                stop: None,
                tenant: "bench".into(),
                deadline: None,
                events: tx,
            };
            if host.submit(req).is_err() {
                return (None, false); // shed at admission
            }
            let mut ttft = None;
            loop {
                match rx.recv() {
                    Ok(Event::Token(_)) => {
                        if ttft.is_none() {
                            ttft = Some(t0.elapsed().as_secs_f64() * 1e3);
                        }
                    }
                    Ok(Event::Done(_)) => return (ttft, true),
                    Ok(Event::Failed(_)) | Err(_) => return (ttft, false),
                }
            }
        }));
    }
    let mut ttfts: Vec<f64> = Vec::new();
    let mut completed = 0usize;
    for w in workers {
        let (ttft, ok) = w.join().expect("load worker");
        if let Some(t) = ttft {
            ttfts.push(t);
        }
        if ok {
            completed += 1;
        }
    }
    let wall = t_start.elapsed().as_secs_f64();
    host.drain();
    handle.join().expect("engine thread");
    ttfts.sort_by(f64::total_cmp);
    let pct = |p: f64| -> f64 {
        if ttfts.is_empty() {
            return 0.0;
        }
        ttfts[((ttfts.len() - 1) as f64 * p).round() as usize]
    };
    let (p50, p99) = (pct(0.50), pct(0.99));
    let shed_rate = (N_REQUESTS - completed) as f64 / N_REQUESTS as f64;
    let sustained_req_s = completed as f64 / wall;
    println!(
        "poisson lanes={lanes:<2}: offered {offered_req_s:.1} req/s, sustained {sustained_req_s:.1} req/s, \
         ttft p50 {p50:.0} ms p99 {p99:.0} ms, shed {:.0}% ({completed}/{N_REQUESTS} completed)",
        shed_rate * 100.0
    );
    vec![
        ("offered_req_s", num(offered_req_s)),
        ("sustained_req_s", num(sustained_req_s)),
        ("completed", num(completed as f64)),
        ("p50_ttft_ms", num(p50)),
        ("p99_ttft_ms", num(p99)),
        ("shed_rate", num(shed_rate)),
    ]
}

/// Mixed-priority overload: a same-instant low-class flood (4× the
/// lane count against a `queue_cap = 2 × lanes` queue, so part of the
/// flood sheds at admission) plus a small high-class trickle arriving
/// once the queue has filled. The weighted scheduler seats queued
/// high-class work before any queued low-class work and evicts the
/// newest queued low request when a high arrival finds the queue full,
/// so the high class should see near-single-request TTFT while the low
/// class eats the queueing delay. `fairness_ratio` (low p99 TTFT over
/// high p99 TTFT) collapsing toward 1 means priority admission stopped
/// working; `hi_pri_p99_ttft_ms` regressing means the high class is
/// being made to wait. Both are gated at lanes = 16 by
/// `scripts/check_bench.sh` (floor and ceiling respectively).
fn priority_overload_stage(model: &ServeModel, lanes: usize) -> Vec<(&'static str, Json)> {
    const N_HI: usize = 4;
    let n_lo = 4 * lanes;
    let cfg = ServeConfig {
        max_lanes: lanes,
        kv_quant: KvQuant::Asym4,
        int_gemm: Some(true),
        arena: Some(true),
        fused_epilogue: Some(true),
        par_backend: Some(ParBackend::Steal),
        queue_cap: 2 * lanes,
        ..ServeConfig::default()
    };
    let eng = Engine::new(model.clone(), &cfg).expect("engine");
    let mut tenants = BTreeMap::new();
    tenants.insert(
        "hi".to_string(),
        TenantPolicy { priority: Priority::High, ..TenantPolicy::default() },
    );
    tenants.insert(
        "lo".to_string(),
        TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() },
    );
    let (host, handle) = spawn_host(eng, HostConfig { tenants, ..HostConfig::default() });
    let spawn_worker = |i: usize, tenant: &'static str| {
        let host = host.clone();
        thread::spawn(move || {
            let prompt: Vec<i32> =
                (0..PROMPT_TOKENS).map(|t| ((i * 31 + t * 7) % 256) as i32).collect();
            let (tx, rx) = mpsc::channel();
            let t0 = Instant::now();
            let req = SubmitReq {
                tokens: prompt,
                n_tokens: NEW_TOKENS,
                temp: 0.0,
                seed: 0xC0FFEE + i as u64,
                stop: None,
                tenant: tenant.into(),
                deadline: None,
                events: tx,
            };
            if host.submit(req).is_err() {
                return (None, false); // shed at admission
            }
            let mut ttft = None;
            loop {
                match rx.recv() {
                    Ok(Event::Token(_)) => {
                        if ttft.is_none() {
                            ttft = Some(t0.elapsed().as_secs_f64() * 1e3);
                        }
                    }
                    Ok(Event::Done(_)) => return (ttft, true),
                    // evicted by a high arrival (or lost the engine):
                    // no completion, but a recorded TTFT still counts
                    Ok(Event::Failed(_)) | Err(_) => return (ttft, false),
                }
            }
        })
    };
    let mut lo_workers = Vec::with_capacity(n_lo);
    for i in 0..n_lo {
        lo_workers.push(spawn_worker(i, "lo"));
    }
    // let the flood land — lanes seated, queue full — before the high
    // class arrives; the interesting case is hi outranking *queued* lo
    thread::sleep(Duration::from_millis(50));
    let mut hi_workers = Vec::with_capacity(N_HI);
    for i in 0..N_HI {
        hi_workers.push(spawn_worker(n_lo + i, "hi"));
        thread::sleep(Duration::from_millis(10));
    }
    let collect = |workers: Vec<thread::JoinHandle<(Option<f64>, bool)>>| {
        let mut ttfts = Vec::new();
        let mut completed = 0usize;
        for w in workers {
            let (ttft, ok) = w.join().expect("priority worker");
            if let Some(t) = ttft {
                ttfts.push(t);
            }
            if ok {
                completed += 1;
            }
        }
        ttfts.sort_by(f64::total_cmp);
        (ttfts, completed)
    };
    let (lo_ttfts, lo_completed) = collect(lo_workers);
    let (hi_ttfts, hi_completed) = collect(hi_workers);
    host.drain();
    handle.join().expect("engine thread");
    let pct = |v: &[f64], p: f64| -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        v[((v.len() - 1) as f64 * p).round() as usize]
    };
    let hi_p99 = pct(&hi_ttfts, 0.99);
    let lo_p99 = pct(&lo_ttfts, 0.99);
    let fairness = lo_p99 / hi_p99.max(1e-9);
    println!(
        "priority lanes={lanes:<2}: hi ttft p99 {hi_p99:.0} ms ({hi_completed}/{N_HI} completed), \
         lo ttft p99 {lo_p99:.0} ms ({lo_completed}/{n_lo} completed), fairness {fairness:.2}x"
    );
    vec![
        ("hi_pri_p99_ttft_ms", num(hi_p99)),
        ("lo_pri_p99_ttft_ms", num(lo_p99)),
        ("fairness_ratio", num(fairness)),
        ("hi_completed", num(hi_completed as f64)),
        ("lo_completed", num(lo_completed as f64)),
    ]
}

/// KV-pressure graceful-degradation stage (lanes = 16 only): a
/// low-class flood sized to fill the pool twice over while the
/// deterministic `kv_pressure` fault withholds half the blocks, plus a
/// high-class trickle arriving once every effective block is committed.
/// Each high arrival preempts the newest low lane — snapshot → whole-
/// reservation release → requeue at the front of its class — so the
/// preempted streams pause and later resume via recompute instead of
/// failing. With an unbounded admission queue and no deadlines, every
/// offered request must therefore still complete:
/// `completed_under_pressure_ratio` (completions / offered) is gated as
/// a *floor* at lanes = 16 by `scripts/check_bench.sh`.
fn kv_pressure_stage(model: &ServeModel, lanes: usize) -> Vec<(&'static str, Json)> {
    const N_HI: usize = 4;
    let n_lo = 2 * lanes;
    // exact capacity for `lanes` concurrent lanes of PROMPT+NEW tokens
    // (K+V × n_layers × ceil(tokens / block_tokens)); the fault then
    // withholds half of it, so only lanes/2 low lanes seat at once and
    // the high trickle must preempt to be seated
    let blocks_per_lane = 2 * 4 * ((PROMPT_TOKENS + NEW_TOKENS).div_ceil(16));
    let max_blocks = lanes * blocks_per_lane;
    let cfg = ServeConfig {
        max_lanes: lanes,
        max_blocks,
        kv_quant: KvQuant::Asym4,
        int_gemm: Some(true),
        arena: Some(true),
        fused_epilogue: Some(true),
        par_backend: Some(ParBackend::Steal),
        preempt: Some(true),
        obs: Some(true),
        ..ServeConfig::default()
    };
    let eng = Engine::new(model.clone(), &cfg).expect("engine");
    let mut tenants = BTreeMap::new();
    tenants.insert(
        "hi".to_string(),
        TenantPolicy { priority: Priority::High, ..TenantPolicy::default() },
    );
    tenants.insert(
        "lo".to_string(),
        TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() },
    );
    let fault = FaultSpec { kv_pressure: max_blocks / 2, ..FaultSpec::default() };
    let (host, handle) = spawn_host(eng, HostConfig { tenants, fault, ..HostConfig::default() });
    let spawn_worker = |i: usize, tenant: &'static str| {
        let host = host.clone();
        thread::spawn(move || {
            let prompt: Vec<i32> =
                (0..PROMPT_TOKENS).map(|t| ((i * 31 + t * 7) % 256) as i32).collect();
            let (tx, rx) = mpsc::channel();
            let req = SubmitReq {
                tokens: prompt,
                n_tokens: NEW_TOKENS,
                temp: 0.0,
                seed: 0xC0FFEE + i as u64,
                stop: None,
                tenant: tenant.into(),
                deadline: None,
                events: tx,
            };
            if host.submit(req).is_err() {
                return false;
            }
            loop {
                match rx.recv() {
                    Ok(Event::Token(_)) => {}
                    Ok(Event::Done(_)) => return true,
                    Ok(Event::Failed(_)) | Err(_) => return false,
                }
            }
        })
    };
    let mut workers = Vec::with_capacity(n_lo + N_HI);
    for i in 0..n_lo {
        workers.push(spawn_worker(i, "lo"));
    }
    // let the flood commit every effective block before the high class
    // arrives — the interesting case is hi preempting *live* lo lanes
    thread::sleep(Duration::from_millis(80));
    for i in 0..N_HI {
        workers.push(spawn_worker(n_lo + i, "hi"));
        thread::sleep(Duration::from_millis(10));
    }
    let offered = workers.len();
    let mut completed = 0usize;
    for w in workers {
        completed += w.join().expect("pressure worker") as usize;
    }
    let stats = host.stats().expect("stats");
    host.drain();
    handle.join().expect("engine thread");
    let ratio = completed as f64 / offered as f64;
    println!(
        "kv-pressure lanes={lanes:<2}: {completed}/{offered} completed (ratio {ratio:.2}), \
         {} preempted, {} resumed, {} recompute tokens, pool {}/{} free",
        stats.engine.preempted,
        stats.engine.resumed,
        stats.engine.resume_recompute_tokens,
        stats.free_blocks,
        stats.max_blocks
    );
    vec![
        ("completed_under_pressure_ratio", num(ratio)),
        ("pressure_offered", num(offered as f64)),
        ("pressure_completed", num(completed as f64)),
        ("pressure_preempted", num(stats.engine.preempted as f64)),
        ("pressure_resumed", num(stats.engine.resumed as f64)),
        ("pressure_recompute_tokens", num(stats.engine.resume_recompute_tokens as f64)),
    ]
}

/// Shared-prefix workload: `REQUESTS` requests over one long shared
/// system prompt with distinct short suffixes. The donor runs through
/// its (chunked) prefill first so its prompt chunks are registered;
/// the sharers then attach at admission and map the system prompt onto
/// the donor's blocks (refcount bump, no compute). Emits the sharing
/// schema rows: `prefix_hit_ratio` (shared / total prompt tokens —
/// gated as a floor at lanes = 16 by `scripts/check_bench.sh`),
/// `kv_bytes_per_token_shared` (effective KV bytes per logical token
/// once shared positions are stored only once) and `admission_p99_ms`
/// (queue-wait p99 under the burst admission).
fn shared_prefix_stage(model: &ServeModel, lanes: usize) -> Vec<(&'static str, Json)> {
    const SYSTEM_TOKENS: usize = 48; // 3 full blocks at the default block_tokens = 16
    const SUFFIX_TOKENS: usize = 8;
    let cfg = ServeConfig {
        max_lanes: lanes,
        kv_quant: KvQuant::Asym4,
        int_gemm: Some(true),
        arena: Some(true),
        fused_epilogue: Some(true),
        par_backend: Some(ParBackend::Steal),
        prefix_share: Some(true),
        obs: Some(true),
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(model.clone(), &cfg).expect("engine");
    let system: Vec<i32> = (0..SYSTEM_TOKENS).map(|t| ((t * 13 + 5) % 256) as i32).collect();
    let prompt = |i: usize| -> Vec<i32> {
        let mut p = system.clone();
        p.extend((0..SUFFIX_TOKENS).map(|t| ((i * 31 + t * 7) % 256) as i32));
        p
    };
    let t0 = Instant::now();
    eng.submit_tokens(prompt(0), NEW_TOKENS, 0.0, 0xC0FFEE).expect("submit donor");
    for _ in 0..64 {
        // sharing is discovered at admission, so the donor must sample
        // its first token (= prefill complete, chunks registered) before
        // the sharers arrive
        if eng.stats.decode_tokens > 0 {
            break;
        }
        eng.step().expect("donor prefill step");
    }
    assert!(eng.stats.decode_tokens > 0, "donor prefill must complete");
    for i in 1..REQUESTS {
        eng.submit_tokens(prompt(i), NEW_TOKENS, 0.0, 0xC0FFEE + i as u64).expect("submit");
    }
    let done = eng.run().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    let prompt_tokens = (REQUESTS * (SYSTEM_TOKENS + SUFFIX_TOKENS)) as f64;
    let shared = eng.stats.prefix_shared_tokens as f64;
    let hit_ratio = shared / prompt_tokens;
    // effective KV bytes per logical token: shared positions occupy no
    // storage of their own, so the layout's per-token cost shrinks by
    // the fraction of the whole stream served from shared blocks
    let layout = eng.kv_bytes_per_token() as f64;
    let kv_shared = layout * (tokens as f64 - shared) / (tokens as f64).max(1.0);
    let adm_p99_ms = eng
        .obs()
        .queue_wait
        .snapshot()
        .quantile_ns(0.99)
        .map(|ns| ns as f64 / 1e6)
        .unwrap_or(0.0);
    println!(
        "shared-prefix lanes={lanes:<2}: hit ratio {hit_ratio:.2} ({shared:.0}/{prompt_tokens:.0} \
         prompt tokens shared), kv {kv_shared:.1} B/token effective vs {layout:.1} unshared, \
         admission p99 {adm_p99_ms:.1} ms, {:.1} tok/s",
        tokens as f64 / wall
    );
    vec![
        ("prefix_hit_ratio", num(hit_ratio)),
        ("prefix_shared_tokens", num(shared)),
        ("kv_bytes_per_token_shared", num(kv_shared)),
        ("admission_p99_ms", num(adm_p99_ms)),
    ]
}

fn main() {
    // the Poisson host would otherwise print one lifecycle log line per
    // request into the bench output (format is latched on first use, so
    // set it before anything logs)
    std::env::set_var("KURTAIL_LOG", "off");
    let meta = bench_meta();
    let mut rng = Rng::new(0);
    let params = Params::init(&meta, &mut rng);
    let spec = ServeQuantSpec {
        weight: QuantScheme::weight4_grouped(64),
        ..ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_ff, &mut rng),
        )
    };
    let int4 = ServeModel::from_params(&params, Some(spec)).expect("int4 model");
    let dense = ServeModel::from_params(&params, None).expect("fp model");

    // warmup (page in weights, spin up the allocator)
    let _ = timed_run(&int4, KvQuant::Asym4, 4, 4, None, None, None);

    // dense f32 sequential baseline (fp weights, fp KV, one lane)
    let (fp_wall, fp_tokens, fp_eng) = timed_run(&dense, KvQuant::Fp, 1, REQUESTS, None, None, None);
    let fp_tok_s = fp_tokens as f64 / fp_wall;
    println!("dense-f32 lane1: {fp_tok_s:.1} tok/s ({fp_tokens} tokens in {fp_wall:.2}s)");

    let mut runs: Vec<Json> = Vec::new();
    let mut lane1_tok_s = 0.0f64;
    let mut last_eng = None;
    for &lanes in &LANES {
        // f32 dequant GEMM on the PR-3 fresh-alloc profile (one side of
        // the int-vs-f32 A/B; both sides share the profile so the gated
        // int_gemm_speedup keeps its PR-3 meaning)
        let (f32_wall, f32_tokens, _) =
            timed_run(&int4, KvQuant::Asym4, lanes, REQUESTS, Some(false), Some(false), Some(0));
        let f32_tok_s = f32_tokens as f64 / f32_wall;
        // integer GEMM on the same PR-3 profile: fresh buffers every
        // iteration, no panel cache, per-call B re-pack
        let (legacy_wall, legacy_tokens, _) =
            timed_run(&int4, KvQuant::Asym4, lanes, REQUESTS, Some(true), Some(false), Some(0));
        let legacy_tok_s = legacy_tokens as f64 / legacy_wall;
        // arena profile with the PR-4 serial-flip epilogue (one side of
        // the fused-epilogue A/B: only the epilogue differs)
        let (serial_wall, serial_tokens, _) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(false),
            Some(ParBackend::Steal),
            Some(true),
        );
        let serial_tok_s = serial_tokens as f64 / serial_wall;
        // arena + fused profile on the static runtime (one side of the
        // work-stealing A/B: only the backend differs)
        let (static_wall, static_tokens, _) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(true),
            Some(ParBackend::Static),
            Some(true),
        );
        let static_tok_s = static_tokens as f64 / static_wall;
        // default profile with observability off (one side of the obs
        // A/B: only the instrumentation differs — clock reads + atomic
        // records; check_bench.sh caps the gap at 2% at lanes = 16)
        let (ooff_wall, ooff_tokens, _) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(true),
            Some(ParBackend::Steal),
            Some(false),
        );
        let obs_off_tok_s = ooff_tokens as f64 / ooff_wall;
        // integer GEMM + arena + panel cache + fused epilogues +
        // work-stealing runtime (the default serving path, obs on)
        let (wall, tokens, eng) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(true),
            Some(ParBackend::Steal),
            Some(true),
        );
        let tok_s = tokens as f64 / wall;
        if lanes == 1 {
            lane1_tok_s = tok_s;
        }
        let speedup = tok_s / lane1_tok_s.max(1e-9);
        let int_speedup = legacy_tok_s / f32_tok_s.max(1e-9);
        let arena_speedup = tok_s / legacy_tok_s.max(1e-9);
        let epilogue_speedup = tok_s / serial_tok_s.max(1e-9);
        let steal_speedup = tok_s / static_tok_s.max(1e-9);
        let obs_overhead = obs_off_tok_s / tok_s.max(1e-9) - 1.0;
        println!(
            "int4 lanes={lanes:<2}: {tok_s:.1} tok/s ({tokens} tokens in {wall:.2}s, \
             {speedup:.2}x vs 1 lane, {arena_speedup:.2}x vs alloc path {legacy_tok_s:.1} tok/s, \
             {epilogue_speedup:.2}x vs serial epilogue {serial_tok_s:.1} tok/s, \
             {steal_speedup:.2}x vs static runtime {static_tok_s:.1} tok/s; \
             int-vs-f32 on the alloc profile: {int_speedup:.2}x over {f32_tok_s:.1} tok/s; \
             obs overhead {:.1}% vs {obs_off_tok_s:.1} tok/s off)",
            obs_overhead * 100.0
        );
        let mut row = vec![
            ("lanes", num(lanes as f64)),
            ("requests", num(REQUESTS as f64)),
            ("tokens", num(tokens as f64)),
            ("wall_s", num(wall)),
            ("tok_s", num(tok_s)),
            ("speedup_vs_lane1", num(speedup)),
            ("speedup_vs_dense_fp", num(tok_s / fp_tok_s.max(1e-9))),
            ("f32_dequant_tok_s", num(f32_tok_s)),
            ("int_gemm_speedup", num(int_speedup)),
            ("legacy_alloc_tok_s", num(legacy_tok_s)),
            ("arena_speedup", num(arena_speedup)),
            ("serial_epilogue_tok_s", num(serial_tok_s)),
            ("epilogue_fused_speedup", num(epilogue_speedup)),
            ("static_par_tok_s", num(static_tok_s)),
            ("steal_speedup", num(steal_speedup)),
            ("obs_off_tok_s", num(obs_off_tok_s)),
            ("obs_overhead", num(obs_overhead)),
        ];
        row.extend(poisson_load(&int4, lanes, tok_s));
        row.extend(priority_overload_stage(&int4, lanes));
        row.extend(shared_prefix_stage(&int4, lanes));
        if lanes == 16 {
            row.extend(kv_pressure_stage(&int4, lanes));
        }
        runs.push(obj(row));
        last_eng = Some(eng);
    }

    let eng = last_eng.expect("at least one run");
    let kv_int4 = eng.kv_bytes_per_token() as f64;
    let kv_dense = fp_eng.dense_kv_bytes_per_token() as f64;
    println!(
        "kv bytes/token: paged-int4 {kv_int4} vs dense f32 {kv_dense} ({:.1}x reduction)",
        kv_dense / kv_int4
    );

    let path = std::env::var("KURTAIL_BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = obj(vec![
        ("bench", js("serve")),
        ("threads", num(num_threads() as f64)),
        (
            "model",
            obj(vec![
                ("arch", js(&meta.arch)),
                ("d_model", num(meta.d_model as f64)),
                ("n_layers", num(meta.n_layers as f64)),
                ("n_heads", num(meta.n_heads as f64)),
                ("d_head", num(meta.d_head as f64)),
                ("d_ff", num(meta.d_ff as f64)),
            ]),
        ),
        ("prompt_tokens", num(PROMPT_TOKENS as f64)),
        ("new_tokens", num(NEW_TOKENS as f64)),
        (
            "kv",
            obj(vec![
                ("paged_int4_bytes_per_token", num(kv_int4)),
                ("dense_f32_bytes_per_token", num(kv_dense)),
                ("reduction", num(kv_dense / kv_int4)),
                ("block_tokens", num(eng.pool().block_tokens as f64)),
            ]),
        ),
        (
            "weights",
            obj(vec![
                ("packed_bytes", num(eng.model().weight_bytes() as f64)),
                ("dense_bytes", num(eng.model().dense_weight_bytes() as f64)),
                (
                    "reduction",
                    num(eng.model().dense_weight_bytes() as f64
                        / eng.model().weight_bytes() as f64),
                ),
                ("panel_cache_bytes", num(eng.panel_cache_bytes() as f64)),
            ]),
        ),
        (
            "baseline_dense_fp32",
            obj(vec![("lanes", num(1.0)), ("tok_s", num(fp_tok_s)), ("wall_s", num(fp_wall))]),
        ),
        ("runs", arr(runs)),
    ]);
    std::fs::write(&path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
