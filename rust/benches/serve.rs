//! Serving-engine benchmark: continuous-batching INT4 decode vs the
//! dense-f32 single-lane path, on a synthetic llama config sized so the
//! weight traversal dominates (d_head 64 → the 4-bit KV layout shows its
//! full ≥6× memory win). No artifacts needed — the engine is native.
//!
//! Every lane count runs the quantized engine five ways:
//!
//! * integer-accumulator GEMM, arena + panel cache + fused column-major
//!   epilogues + work-stealing runtime (`tok_s` — the default serving
//!   path),
//! * the same arena profile with the PR-4 **serial-flip** epilogue
//!   (`ServeConfig::fused_epilogue = Some(false)`):
//!   `serial_epilogue_tok_s`, and `epilogue_fused_speedup = tok_s /
//!   serial_epilogue_tok_s` isolates the fused-epilogue win (gated by
//!   `scripts/check_bench.sh` at lanes = 16),
//! * the same arena+fused profile on the **static** scoped-thread
//!   runtime (`ServeConfig::par_backend = Some(Static)`):
//!   `static_par_tok_s`, and `steal_speedup = tok_s / static_par_tok_s`
//!   isolates the work-stealing win on the mixed serving batch (the
//!   skewed-kernel steal case lives in `benches/kernels.rs`),
//! * integer GEMM on the PR-3 fresh-alloc profile
//!   (`ServeConfig::arena = Some(false)`, `panel_cache = Some(0)`):
//!   `legacy_alloc_tok_s`, and `arena_speedup = tok_s /
//!   legacy_alloc_tok_s` isolates the arena + panel win,
//! * f32 dequant GEMM on the same PR-3 profile (`f32_dequant_tok_s`):
//!   `int_gemm_speedup = legacy_alloc_tok_s / f32_dequant_tok_s` keeps
//!   the PR-3 definition of the INT4×INT4 headline — both of its sides
//!   on the fresh-alloc path — so the committed baseline floor stays
//!   comparable (`scripts/check_bench.sh` gates the speedups; each A/B
//!   isolates one knob so one knob's gain can't mask or fake another's
//!   regression).
//!
//! Writes `BENCH_serve.json` (path override: `KURTAIL_BENCH_SERVE_JSON`)
//! with tokens/sec at 1/4/16 concurrent sequences and KV bytes/token for
//! the paged 4-bit pool vs the dense f32 cache. `scripts/bench.sh`
//! drops it at the repo root, next to `BENCH_kernels.json`.

use std::time::Instant;

use kurtail::config::{KvQuant, QuantScheme};
use kurtail::model::Params;
use kurtail::runtime::{ConfigMeta, ParamSpec};
use kurtail::serve::{Engine, ParBackend, ServeConfig, ServeModel, ServeQuantSpec};
use kurtail::tensor::hadamard::random_hadamard;
use kurtail::util::json::{arr, num, obj, s as js, Json};
use kurtail::util::par::num_threads;
use kurtail::util::Rng;

const LANES: [usize; 3] = [1, 4, 16];
const REQUESTS: usize = 16;
const PROMPT_TOKENS: usize = 8;
const NEW_TOKENS: usize = 48;

/// Synthetic serving config: llama arch, d=256, 4 heads × d_head 64.
fn bench_meta() -> ConfigMeta {
    let (l, d, ff, v, h) = (4usize, 256usize, 512usize, 256usize, 4usize);
    let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.into(), shape };
    ConfigMeta {
        name: "servebench".into(),
        vocab: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_head: d / h,
        d_ff: ff,
        seq_len: 128,
        arch: "llama".into(),
        n_experts: 1,
        top_k: 1,
        train_batch: 1,
        eval_batch: 1,
        cap_batch: 1,
        decode_batch: 1,
        spin_batch: 1,
        param_specs: vec![
            spec("embed", vec![v, d]),
            spec("ln1", vec![l, d]),
            spec("wq", vec![l, d, d]),
            spec("wk", vec![l, d, d]),
            spec("wv", vec![l, d, d]),
            spec("wo", vec![l, d, d]),
            spec("ln2", vec![l, d]),
            spec("wg", vec![l, d, ff]),
            spec("wu", vec![l, d, ff]),
            spec("wd", vec![l, ff, d]),
            spec("lnf", vec![d]),
            spec("head", vec![v, d]),
        ],
    }
}

fn submit_all(eng: &mut Engine, requests: usize) {
    for i in 0..requests {
        let prompt: Vec<i32> = (0..PROMPT_TOKENS).map(|t| ((i * 31 + t * 7) % 256) as i32).collect();
        eng.submit_tokens(prompt, NEW_TOKENS, 0.0, 0xC0FFEE + i as u64).expect("submit");
    }
}

/// One timed engine run; returns (wall seconds, total tokens processed).
/// Engine construction (weight packing, panel build, arena sizing) sits
/// outside the timed region — it is per-deployment, not per-request.
#[allow(clippy::too_many_arguments)]
fn timed_run_cfg(
    model: &ServeModel,
    kv: KvQuant,
    lanes: usize,
    requests: usize,
    int_gemm: Option<bool>,
    arena: Option<bool>,
    panel_cache: Option<usize>,
    fused_epilogue: Option<bool>,
    par_backend: Option<ParBackend>,
) -> (f64, usize, Engine) {
    let cfg = ServeConfig {
        max_lanes: lanes,
        kv_quant: kv,
        int_gemm,
        arena,
        panel_cache,
        fused_epilogue,
        par_backend,
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(model.clone(), &cfg).expect("engine");
    submit_all(&mut eng, requests);
    let t0 = Instant::now();
    let done = eng.run().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    (wall, tokens, eng)
}

fn timed_run(
    model: &ServeModel,
    kv: KvQuant,
    lanes: usize,
    requests: usize,
    int_gemm: Option<bool>,
    arena: Option<bool>,
    panel_cache: Option<usize>,
) -> (f64, usize, Engine) {
    timed_run_cfg(model, kv, lanes, requests, int_gemm, arena, panel_cache, None, None)
}

fn main() {
    let meta = bench_meta();
    let mut rng = Rng::new(0);
    let params = Params::init(&meta, &mut rng);
    let spec = ServeQuantSpec {
        weight: QuantScheme::weight4_grouped(64),
        ..ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_ff, &mut rng),
        )
    };
    let int4 = ServeModel::from_params(&params, Some(spec)).expect("int4 model");
    let dense = ServeModel::from_params(&params, None).expect("fp model");

    // warmup (page in weights, spin up the allocator)
    let _ = timed_run(&int4, KvQuant::Asym4, 4, 4, None, None, None);

    // dense f32 sequential baseline (fp weights, fp KV, one lane)
    let (fp_wall, fp_tokens, fp_eng) = timed_run(&dense, KvQuant::Fp, 1, REQUESTS, None, None, None);
    let fp_tok_s = fp_tokens as f64 / fp_wall;
    println!("dense-f32 lane1: {fp_tok_s:.1} tok/s ({fp_tokens} tokens in {fp_wall:.2}s)");

    let mut runs: Vec<Json> = Vec::new();
    let mut lane1_tok_s = 0.0f64;
    let mut last_eng = None;
    for &lanes in &LANES {
        // f32 dequant GEMM on the PR-3 fresh-alloc profile (one side of
        // the int-vs-f32 A/B; both sides share the profile so the gated
        // int_gemm_speedup keeps its PR-3 meaning)
        let (f32_wall, f32_tokens, _) =
            timed_run(&int4, KvQuant::Asym4, lanes, REQUESTS, Some(false), Some(false), Some(0));
        let f32_tok_s = f32_tokens as f64 / f32_wall;
        // integer GEMM on the same PR-3 profile: fresh buffers every
        // iteration, no panel cache, per-call B re-pack
        let (legacy_wall, legacy_tokens, _) =
            timed_run(&int4, KvQuant::Asym4, lanes, REQUESTS, Some(true), Some(false), Some(0));
        let legacy_tok_s = legacy_tokens as f64 / legacy_wall;
        // arena profile with the PR-4 serial-flip epilogue (one side of
        // the fused-epilogue A/B: only the epilogue differs)
        let (serial_wall, serial_tokens, _) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(false),
            Some(ParBackend::Steal),
        );
        let serial_tok_s = serial_tokens as f64 / serial_wall;
        // arena + fused profile on the static runtime (one side of the
        // work-stealing A/B: only the backend differs)
        let (static_wall, static_tokens, _) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(true),
            Some(ParBackend::Static),
        );
        let static_tok_s = static_tokens as f64 / static_wall;
        // integer GEMM + arena + panel cache + fused epilogues +
        // work-stealing runtime (the default serving path)
        let (wall, tokens, eng) = timed_run_cfg(
            &int4,
            KvQuant::Asym4,
            lanes,
            REQUESTS,
            Some(true),
            Some(true),
            None,
            Some(true),
            Some(ParBackend::Steal),
        );
        let tok_s = tokens as f64 / wall;
        if lanes == 1 {
            lane1_tok_s = tok_s;
        }
        let speedup = tok_s / lane1_tok_s.max(1e-9);
        let int_speedup = legacy_tok_s / f32_tok_s.max(1e-9);
        let arena_speedup = tok_s / legacy_tok_s.max(1e-9);
        let epilogue_speedup = tok_s / serial_tok_s.max(1e-9);
        let steal_speedup = tok_s / static_tok_s.max(1e-9);
        println!(
            "int4 lanes={lanes:<2}: {tok_s:.1} tok/s ({tokens} tokens in {wall:.2}s, \
             {speedup:.2}x vs 1 lane, {arena_speedup:.2}x vs alloc path {legacy_tok_s:.1} tok/s, \
             {epilogue_speedup:.2}x vs serial epilogue {serial_tok_s:.1} tok/s, \
             {steal_speedup:.2}x vs static runtime {static_tok_s:.1} tok/s; \
             int-vs-f32 on the alloc profile: {int_speedup:.2}x over {f32_tok_s:.1} tok/s)"
        );
        runs.push(obj(vec![
            ("lanes", num(lanes as f64)),
            ("requests", num(REQUESTS as f64)),
            ("tokens", num(tokens as f64)),
            ("wall_s", num(wall)),
            ("tok_s", num(tok_s)),
            ("speedup_vs_lane1", num(speedup)),
            ("speedup_vs_dense_fp", num(tok_s / fp_tok_s.max(1e-9))),
            ("f32_dequant_tok_s", num(f32_tok_s)),
            ("int_gemm_speedup", num(int_speedup)),
            ("legacy_alloc_tok_s", num(legacy_tok_s)),
            ("arena_speedup", num(arena_speedup)),
            ("serial_epilogue_tok_s", num(serial_tok_s)),
            ("epilogue_fused_speedup", num(epilogue_speedup)),
            ("static_par_tok_s", num(static_tok_s)),
            ("steal_speedup", num(steal_speedup)),
        ]));
        last_eng = Some(eng);
    }

    let eng = last_eng.expect("at least one run");
    let kv_int4 = eng.kv_bytes_per_token() as f64;
    let kv_dense = fp_eng.dense_kv_bytes_per_token() as f64;
    println!(
        "kv bytes/token: paged-int4 {kv_int4} vs dense f32 {kv_dense} ({:.1}x reduction)",
        kv_dense / kv_int4
    );

    let path = std::env::var("KURTAIL_BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = obj(vec![
        ("bench", js("serve")),
        ("threads", num(num_threads() as f64)),
        (
            "model",
            obj(vec![
                ("arch", js(&meta.arch)),
                ("d_model", num(meta.d_model as f64)),
                ("n_layers", num(meta.n_layers as f64)),
                ("n_heads", num(meta.n_heads as f64)),
                ("d_head", num(meta.d_head as f64)),
                ("d_ff", num(meta.d_ff as f64)),
            ]),
        ),
        ("prompt_tokens", num(PROMPT_TOKENS as f64)),
        ("new_tokens", num(NEW_TOKENS as f64)),
        (
            "kv",
            obj(vec![
                ("paged_int4_bytes_per_token", num(kv_int4)),
                ("dense_f32_bytes_per_token", num(kv_dense)),
                ("reduction", num(kv_dense / kv_int4)),
                ("block_tokens", num(eng.pool().block_tokens as f64)),
            ]),
        ),
        (
            "weights",
            obj(vec![
                ("packed_bytes", num(eng.model().weight_bytes() as f64)),
                ("dense_bytes", num(eng.model().dense_weight_bytes() as f64)),
                (
                    "reduction",
                    num(eng.model().dense_weight_bytes() as f64
                        / eng.model().weight_bytes() as f64),
                ),
                ("panel_cache_bytes", num(eng.panel_cache_bytes() as f64)),
            ]),
        ),
        (
            "baseline_dense_fp32",
            obj(vec![("lanes", num(1.0)), ("tok_s", num(fp_tok_s)), ("wall_s", num(fp_wall))]),
        ),
        ("runs", arr(runs)),
    ]);
    std::fs::write(&path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
