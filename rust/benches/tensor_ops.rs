//! L3 host-tensor micro-benchmarks: the coordinator-side hot loops
//! (blocked matmul, gram accumulation, Cholesky, FWHT, fake-quant,
//! kurtosis). These dominate GPTQ and rotation fusion time.

use kurtail::config::QuantScheme;
use kurtail::quant::{fake_quant_rows, rtn_quantize};
use kurtail::tensor::hadamard::fwht_rows;
use kurtail::tensor::linalg::{cholesky, spd_inverse};
use kurtail::tensor::matmul::{gram, matmul};
use kurtail::tensor::stats::kurtosis_rows;
use kurtail::tensor::Tensor;
use kurtail::util::bench::Bench;
use kurtail::util::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0);

    for n in [64usize, 128, 256] {
        let a = Tensor::randn(&[n, n], 1.0, &mut rng);
        let c = Tensor::randn(&[n, n], 1.0, &mut rng);
        b.run(&format!("matmul_{n}x{n}x{n}"), || matmul(&a, &c));
    }
    for (m, n) in [(2048usize, 128usize), (2048, 256)] {
        let a = Tensor::randn(&[m, n], 1.0, &mut rng);
        b.run(&format!("gram_{m}x{n}"), || gram(&a));
    }
    for n in [64usize, 128, 256] {
        let a = Tensor::randn(&[n + 8, n], 1.0, &mut rng);
        let h = gram(&a);
        b.run(&format!("cholesky_{n}"), || cholesky(&h).unwrap());
        b.run(&format!("spd_inverse_{n}"), || spd_inverse(&h).unwrap());
    }
    for n in [64usize, 256] {
        let x = Tensor::randn(&[1024, n], 1.0, &mut rng);
        b.run(&format!("fwht_rows_1024x{n}"), || {
            let mut y = x.clone();
            fwht_rows(&mut y);
            y
        });
        b.run(&format!("kurtosis_rows_1024x{n}"), || kurtosis_rows(&x));
        b.run(&format!("fake_quant_rows_1024x{n}"), || {
            fake_quant_rows(&x, &QuantScheme::act4())
        });
    }
    let w = Tensor::randn(&[256, 256], 0.1, &mut rng);
    b.run("rtn_quantize_256x256", || rtn_quantize(&w, &QuantScheme::weight4()));
}
