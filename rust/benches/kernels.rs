//! L1 kernel micro-benchmarks through the PJRT runtime: the standalone
//! Pallas artifacts (quant_matmul, hadamard, kurtosis) at several sizes,
//! plus the fused quantized NLL graph. Feeds EXPERIMENTS.md §Perf.

use kurtail::runtime::{Runtime, Value};
use kurtail::tensor::{IntTensor, Tensor};
use kurtail::util::bench::Bench;
use kurtail::util::Rng;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP kernels bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let mut b = Bench::new();
    let mut rng = Rng::new(0);

    for (m, k, n) in [(256usize, 128usize, 128usize), (512, 256, 256), (1024, 512, 512)] {
        let name = format!("quant_matmul_{m}x{k}x{n}");
        let art = rt.load(&name).expect("load");
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 0.3, &mut rng);
        b.run(&format!("pjrt/{name}"), || {
            art.run(&[Value::F32(x.clone()), Value::F32(w.clone())]).unwrap()
        });
    }

    for (m, k) in [(1024usize, 64usize), (1024, 256), (4096, 512)] {
        let name = format!("hadamard_{m}x{k}");
        let art = rt.load(&name).expect("load");
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        b.run(&format!("pjrt/{name}"), || art.run(&[Value::F32(x.clone())]).unwrap());
    }

    for (m, k) in [(4096usize, 64usize), (4096, 256)] {
        let name = format!("kurtosis_{m}x{k}");
        let art = rt.load(&name).expect("load");
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        b.run(&format!("pjrt/{name}"), || art.run(&[Value::F32(x.clone())]).unwrap());
    }

    // whole quantized forward (the L2 hot graph) on the tiny config
    let meta = rt.manifest.config("tiny").unwrap().clone();
    let art = rt.load("fwd_nll_quant_tiny").expect("load");
    let params: Vec<Value> = meta
        .param_specs
        .iter()
        .map(|p| Value::F32(Tensor::randn(&p.shape, 0.05, &mut rng)))
        .collect();
    let tokens = IntTensor::new(
        (0..meta.eval_batch * meta.seq_len).map(|i| (i % 250) as i32).collect(),
        vec![meta.eval_batch, meta.seq_len],
    );
    let mask = Tensor::ones(&[meta.eval_batch, meta.seq_len]);
    let mut inputs = params.clone();
    inputs.push(Value::F32(Tensor::eye(meta.d_head)));
    inputs.push(Value::F32(Tensor::eye(meta.d_head)));
    inputs.push(Value::F32(Tensor::eye(meta.d_ff)));
    inputs.push(Value::I32(tokens));
    inputs.push(Value::F32(mask));
    b.run("pjrt/fwd_nll_quant_tiny(b8xs64)", || art.run(&inputs).unwrap());

    let fp = rt.load("fwd_nll_tiny").expect("load");
    let mut fp_inputs = params;
    fp_inputs.push(inputs[inputs.len() - 2].clone());
    fp_inputs.push(inputs[inputs.len() - 1].clone());
    b.run("pjrt/fwd_nll_tiny(b8xs64)", || fp.run(&fp_inputs).unwrap());
}
