//! Kernel micro-benchmarks, two tiers:
//!
//! 1. **Host kernels** (always runs): the scalar seed kernels vs the
//!    packed-parallel rewrites at 256/512/1024/2048 dims, written to
//!    `BENCH_kernels.json` (path override: `KURTAIL_BENCH_JSON`) so
//!    `scripts/bench.sh` can track the perf trajectory PR-over-PR.
//! 2. **PJRT artifacts** (needs `make artifacts`): the standalone Pallas
//!    kernels and the fused quantized NLL graph. Feeds EXPERIMENTS.md §Perf.

use kurtail::config::QuantScheme;
use kurtail::quant::fakequant::{fake_quant_rows, fake_quant_rows_ref};
use kurtail::quant::gptq::{gptq_quantize_with_factor, GptqFactor};
use kurtail::runtime::{Runtime, Value};
use kurtail::serve::Int4Weight;
use kurtail::tensor::hadamard::{fwht_rows, fwht_rows_ref};
use kurtail::tensor::matmul::{gram, gram_ref, matmul, matmul_into_ref};
use kurtail::tensor::{IntTensor, Tensor};
use kurtail::util::bench::{Bench, Stats};
use kurtail::util::json::{arr, num, obj, s as js, Json};
use kurtail::util::par::num_threads;
use kurtail::util::Rng;

const SIZES: [usize; 4] = [256, 512, 1024, 2048];
/// Rows of the batched row-kernels (FWHT, fake-quant) at every dim.
const BATCH_ROWS: usize = 1024;
/// Activation lanes of the serving-GEMM comparison (the decode batch).
const GEMM_LANES: usize = 16;
/// Weight scale-group rows of the serving-GEMM comparison.
const GEMM_GROUP: usize = 64;

fn main() {
    host_kernels();
    pjrt_kernels();
}

/// Retune the sampler for the problem size: the 2048-dim scalar
/// baselines run for seconds per iteration.
fn tune(b: &mut Bench, d: usize) {
    let (min_time_s, warmup_s, min_samples) = match d {
        0..=512 => (0.2, 0.05, 5),
        513..=1024 => (0.0, 0.0, 3),
        _ => (0.0, 0.0, 2),
    };
    b.min_time_s = min_time_s;
    b.warmup_s = warmup_s;
    b.min_samples = min_samples;
}

/// One (kernel, dim) comparison entry: `baseline` is the reference
/// implementation (scalar seed kernel for the PR-1 rewrites, the f32
/// dequant GEMM for `int4_gemm`), `new` the current fast path.
fn comparison(kernel: &str, d: usize, shape: String, baseline: Stats, new: Stats) -> Json {
    let speedup = baseline.mean_ns / new.mean_ns.max(1.0);
    println!("  {kernel}@{d}: new path is {speedup:.2}x the baseline kernel");
    obj(vec![
        ("kernel", js(kernel)),
        ("dim", num(d as f64)),
        ("shape", js(&shape)),
        ("baseline_ns", num(baseline.mean_ns)),
        ("new_ns", num(new.mean_ns)),
        ("speedup", num(speedup)),
    ])
}

fn host_kernels() {
    let mut b = Bench::quick();
    let mut rng = Rng::new(0);
    let mut comparisons: Vec<Json> = Vec::new();
    let scheme = QuantScheme::act4();

    for &d in &SIZES {
        tune(&mut b, d);
        let a = Tensor::randn(&[d, d], 1.0, &mut rng);
        let w = Tensor::randn(&[d, d], 0.3, &mut rng);

        let scalar = b.run(&format!("host/matmul_ref_{d}x{d}x{d}"), || {
            let mut c = vec![0.0f32; d * d];
            matmul_into_ref(&a.data, &w.data, &mut c, d, d, d);
            c
        });
        let packed = b.run(&format!("host/matmul_packed_{d}x{d}x{d}"), || matmul(&a, &w));
        comparisons.push(comparison("matmul", d, format!("{d}x{d}x{d}"), scalar, packed));

        let scalar = b.run(&format!("host/gram_ref_{d}x{d}"), || gram_ref(&a));
        let packed = b.run(&format!("host/gram_packed_{d}x{d}"), || gram(&a));
        comparisons.push(comparison("gram", d, format!("{d}x{d}"), scalar, packed));

        let x = Tensor::randn(&[BATCH_ROWS, d], 1.0, &mut rng);
        let scalar = b.run(&format!("host/fwht_ref_{BATCH_ROWS}x{d}"), || {
            let mut y = x.clone();
            fwht_rows_ref(&mut y);
            y
        });
        let packed = b.run(&format!("host/fwht_parallel_{BATCH_ROWS}x{d}"), || {
            let mut y = x.clone();
            fwht_rows(&mut y);
            y
        });
        comparisons.push(comparison("fwht_rows", d, format!("{BATCH_ROWS}x{d}"), scalar, packed));

        let scalar =
            b.run(&format!("host/fakequant_ref_{BATCH_ROWS}x{d}"), || fake_quant_rows_ref(&x, &scheme));
        let packed =
            b.run(&format!("host/fakequant_parallel_{BATCH_ROWS}x{d}"), || fake_quant_rows(&x, &scheme));
        comparisons.push(comparison("fake_quant_rows", d, format!("{BATCH_ROWS}x{d}"), scalar, packed));

        // serving GEMM: f32 dequant (fake-quant acts, then dequant dot)
        // vs the int8×int4 i32-accumulator path, at the decode batch
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(GEMM_GROUP));
        let lanes = Tensor::randn(&[GEMM_LANES, d], 1.0, &mut rng);
        let f32_path = b.run(&format!("host/int4_gemm_f32_{GEMM_LANES}x{d}x{d}"), || {
            iw.matmul(&fake_quant_rows(&lanes, &scheme))
        });
        let int_path = b.run(&format!("host/int4_gemm_i32_{GEMM_LANES}x{d}x{d}"), || {
            iw.quant_matmul(&lanes, &scheme)
        });
        comparisons.push(comparison(
            "int4_gemm",
            d,
            format!("{GEMM_LANES}x{d}x{d}"),
            f32_path,
            int_path,
        ));

        // i8 panel cache A/B on the integer GEMM: per-call nibble
        // unpack (baseline) vs cached contiguous i8 panels (new)
        let mut iw_hot = iw.clone();
        iw_hot.build_panels();
        let cold = b.run(&format!("host/int4_gemm_unpack_{GEMM_LANES}x{d}x{d}"), || {
            iw.quant_matmul(&lanes, &scheme)
        });
        let hot = b.run(&format!("host/int4_gemm_panel_{GEMM_LANES}x{d}x{d}"), || {
            iw_hot.quant_matmul(&lanes, &scheme)
        });
        comparisons.push(comparison(
            "int4_gemm_panel",
            d,
            format!("{GEMM_LANES}x{d}x{d}"),
            cold,
            hot,
        ));
    }

    // work-stealing vs static row-chunking on a *skewed* GPTQ workload:
    // 7/8 of the output channels are all-zero, so their per-step error
    // feedback short-circuits and nearly all the work concentrates in
    // the dense tail — the static chunker strands it on one thread,
    // the steal backend's finer fixed grid rebalances it. The entry's
    // `speedup` field is the steal-vs-static ratio
    // (`gptq_skewed_steal`), tracked like every other comparison.
    {
        let (gk, gn) = (512usize, 512usize);
        tune(&mut b, gk);
        let mut wdata = vec![0.0f32; gk * gn];
        let dense_cols = gn / 8;
        let dense = Tensor::randn(&[gk, dense_cols], 0.3, &mut rng);
        for i in 0..gk {
            for jj in 0..dense_cols {
                wdata[i * gn + (gn - dense_cols + jj)] = dense.data[i * dense_cols + jj];
            }
        }
        let w = Tensor::new(wdata, vec![gk, gn]);
        // correlated activations → non-diagonal Hessian (damped SPD in prepare)
        let h = kurtail::tensor::matmul::gram(&Tensor::randn(&[256, gk], 1.0, &mut rng));
        let factor = GptqFactor::prepare(&h);
        let wscheme = QuantScheme::weight4();
        let prior = std::env::var("KURTAIL_PAR").ok();
        std::env::set_var("KURTAIL_PAR", "static");
        let static_stats =
            b.run(&format!("host/gptq_skewed_static_{gk}x{gn}"), || gptq_quantize_with_factor(&w, &factor, &wscheme));
        std::env::set_var("KURTAIL_PAR", "steal");
        let steal_stats =
            b.run(&format!("host/gptq_skewed_steal_{gk}x{gn}"), || gptq_quantize_with_factor(&w, &factor, &wscheme));
        match prior {
            Some(v) => std::env::set_var("KURTAIL_PAR", v),
            None => std::env::remove_var("KURTAIL_PAR"),
        }
        comparisons.push(comparison("gptq_skewed_steal", gk, format!("{gk}x{gn}"), static_stats, steal_stats));
    }

    let path =
        std::env::var("KURTAIL_BENCH_JSON").unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    b.write_json(
        &path,
        vec![
            ("bench", js("kernels")),
            ("threads", num(num_threads() as f64)),
            (
                "host_parallelism",
                num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64),
            ),
            ("sizes", arr(SIZES.iter().map(|&d| num(d as f64)).collect())),
            ("comparisons", arr(comparisons)),
        ],
    )
    .expect("write bench json");
    println!("wrote {path}");
}

fn pjrt_kernels() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP pjrt kernels bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let mut b = Bench::new();
    let mut rng = Rng::new(0);

    for (m, k, n) in [(256usize, 128usize, 128usize), (512, 256, 256), (1024, 512, 512)] {
        let name = format!("quant_matmul_{m}x{k}x{n}");
        let art = rt.load(&name).expect("load");
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 0.3, &mut rng);
        b.run(&format!("pjrt/{name}"), || {
            art.run(&[Value::F32(x.clone()), Value::F32(w.clone())]).unwrap()
        });
    }

    for (m, k) in [(1024usize, 64usize), (1024, 256), (4096, 512)] {
        let name = format!("hadamard_{m}x{k}");
        let art = rt.load(&name).expect("load");
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        b.run(&format!("pjrt/{name}"), || art.run(&[Value::F32(x.clone())]).unwrap());
    }

    for (m, k) in [(4096usize, 64usize), (4096, 256)] {
        let name = format!("kurtosis_{m}x{k}");
        let art = rt.load(&name).expect("load");
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        b.run(&format!("pjrt/{name}"), || art.run(&[Value::F32(x.clone())]).unwrap());
    }

    // whole quantized forward (the L2 hot graph) on the tiny config
    let meta = rt.manifest.config("tiny").unwrap().clone();
    let art = rt.load("fwd_nll_quant_tiny").expect("load");
    let params: Vec<Value> = meta
        .param_specs
        .iter()
        .map(|p| Value::F32(Tensor::randn(&p.shape, 0.05, &mut rng)))
        .collect();
    let tokens = IntTensor::new(
        (0..meta.eval_batch * meta.seq_len).map(|i| (i % 250) as i32).collect(),
        vec![meta.eval_batch, meta.seq_len],
    );
    let mask = Tensor::ones(&[meta.eval_batch, meta.seq_len]);
    let mut inputs = params.clone();
    inputs.push(Value::F32(Tensor::eye(meta.d_head)));
    inputs.push(Value::F32(Tensor::eye(meta.d_head)));
    inputs.push(Value::F32(Tensor::eye(meta.d_ff)));
    inputs.push(Value::I32(tokens));
    inputs.push(Value::F32(mask));
    b.run("pjrt/fwd_nll_quant_tiny(b8xs64)", || art.run(&inputs).unwrap());

    let fp = rt.load("fwd_nll_tiny").expect("load");
    let mut fp_inputs = params;
    fp_inputs.push(inputs[inputs.len() - 2].clone());
    fp_inputs.push(inputs[inputs.len() - 1].clone());
    b.run("pjrt/fwd_nll_tiny(b8xs64)", || fp.run(&fp_inputs).unwrap());
}
