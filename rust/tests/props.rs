//! Property-based coordinator invariants (proptest-style, via the
//! in-tree `util::proptest` harness). No artifacts needed — these pin
//! the host-side math that the pipeline trusts.

mod common;
use common::serve_test_meta;

use std::collections::{BTreeMap, HashSet};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use kurtail::calib::{corpus, ByteTokenizer, CorpusKind, TokenDataset, World};
use kurtail::config::QuantScheme;
use kurtail::quant::fakequant::{fake_quant_rows_with_threads, row_scale};
use kurtail::quant::{fake_quant_rows, fake_quant_rows_asym, rtn_quantize};
use kurtail::quant::gptq::{gptq_quantize, hessian_error};
use kurtail::rotation::blockdiag_heads;
use kurtail::tensor::hadamard::{
    fwht_rows, fwht_rows_with_threads, hadamard_matrix, orthogonality_error, random_hadamard,
};
use kurtail::tensor::matmul::{
    gram, gram_accumulate_with_threads, gram_with_threads, matmul, matmul_with_threads, rows_matmul,
};
use kurtail::config::KvQuant;
use kurtail::model::Params;
use kurtail::obs::Histogram;
use kurtail::serve::daemon::fault::FaultSpec;
use kurtail::serve::daemon::{spawn_host_reloadable, spawn_host_supervised, Event, SubmitReq};
use kurtail::serve::{
    ConfigCell, Engine, Int4Weight, KvPool, ParBackend, Priority, QuantActs, RuntimeConfig, SeqKv,
    ServeConfig, ServeError, ServeModel, ServeQuantSpec, TenantPolicy,
};
use kurtail::tensor::stats::{kurtail_loss, kurtosis};
use kurtail::tensor::Tensor;
use kurtail::util::proptest::{check, prop_assert, prop_close};
use kurtail::util::Rng;

/// Naive triple-loop matmul — the ground truth the packed kernels are
/// checked against at awkward (odd, non-block-aligned) shapes.
fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for kk in 0..k {
                s += a.data[i * k + kk] * b.data[kk * n + j];
            }
            c.data[i * n + j] = s;
        }
    }
    c
}

#[test]
fn prop_packed_matmul_matches_naive_at_odd_shapes() {
    check(15, |rng| {
        // odd sizes straddling the panel (NR=8), microkernel (MR=4) and
        // thread-chunk boundaries; 33³ > the packed-path threshold
        // (PACK_MIN_MADDS = 32·1024), so every draw hits the packed kernel
        let m = 33 + 2 * rng.below(60); // 33..151, odd
        let k = 33 + 2 * rng.below(60);
        let n = 33 + 2 * rng.below(60);
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[k, n], 1.0, rng);
        let want = naive_matmul(&a, &b);
        for threads in [1usize, 3, 8] {
            let got = matmul_with_threads(&a, &b, threads);
            prop_assert(
                got.max_abs_diff(&want) < 1e-3,
                &format!("packed matmul {m}x{k}x{n} (t={threads}) within 1e-3 of naive"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_packed_gram_matches_naive_at_odd_shapes() {
    check(15, |rng| {
        let m = 21 + 2 * rng.below(60);
        let n = 13 + 2 * rng.below(50);
        let a = Tensor::randn(&[m, n], 1.0, rng);
        let want = naive_matmul(&a.t(), &a);
        for threads in [1usize, 2, 8] {
            let got = gram_with_threads(&a, threads);
            prop_assert(
                got.max_abs_diff(&want) < 1e-3,
                &format!("gram {m}x{n} (t={threads}) within 1e-3 of naive"),
            )?;
        }
        // streamed accumulation over odd-sized chunks agrees too
        let mut h = Tensor::zeros(&[n, n]);
        let split = 1 + rng.below(m - 1);
        for (r0, r1) in [(0, split), (split, m)] {
            let chunk = Tensor::new(a.data[r0 * n..r1 * n].to_vec(), vec![r1 - r0, n]);
            gram_accumulate_with_threads(&mut h, &chunk, 1 + rng.below(8));
        }
        prop_assert(h.max_abs_diff(&want) < 1e-3, "streamed gram_accumulate matches naive")
    });
}

#[test]
fn prop_kernels_deterministic_across_threads() {
    // bitwise — the parallel partition must never change the per-element
    // accumulation order (KURTAIL_THREADS=1 vs 8 yield identical bits)
    check(10, |rng| {
        let m = 33 + rng.below(64);
        let k = 33 + rng.below(64);
        let n = 33 + rng.below(64);
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[k, n], 1.0, rng);
        let c1 = matmul_with_threads(&a, &b, 1);
        let c8 = matmul_with_threads(&a, &b, 8);
        prop_assert(c1.data == c8.data, "matmul bitwise deterministic across threads")?;

        let g1 = gram_with_threads(&a, 1);
        let g8 = gram_with_threads(&a, 8);
        prop_assert(g1.data == g8.data, "gram bitwise deterministic across threads")?;

        let d = 1usize << (4 + rng.below(4));
        let x = Tensor::randn(&[m, d], 1.0, rng);
        let mut f1 = x.clone();
        fwht_rows_with_threads(&mut f1, 1);
        let mut f8 = x.clone();
        fwht_rows_with_threads(&mut f8, 8);
        prop_assert(f1.data == f8.data, "fwht bitwise deterministic across threads")?;

        let s = QuantScheme::act4();
        let q1 = fake_quant_rows_with_threads(&x, &s, 1);
        let q8 = fake_quant_rows_with_threads(&x, &s, 8);
        prop_assert(q1.data == q8.data, "fake-quant bitwise deterministic across threads")
    });
}

#[test]
fn prop_hadamard_orthogonal_all_sizes() {
    check(40, |rng| {
        let n = 1usize << (1 + rng.below(8)); // 2..256
        let h = random_hadamard(n, rng);
        prop_assert(orthogonality_error(&h) < 1e-3, "random hadamard orthogonal")
    });
}

#[test]
fn prop_fwht_equals_matrix_product() {
    check(25, |rng| {
        let n = 1usize << (2 + rng.below(6));
        let m = 1 + rng.below(16);
        let x = Tensor::randn(&[m, n], 1.0, rng);
        let want = rows_matmul(&x, &hadamard_matrix(n));
        let mut got = x.clone();
        fwht_rows(&mut got);
        prop_close(got.max_abs_diff(&want), 0.0, 1e-3, "fwht == H matmul")
    });
}

#[test]
fn prop_rotation_preserves_row_norms() {
    check(25, |rng| {
        let n = 1usize << (3 + rng.below(4));
        let x = Tensor::randn(&[8, n], 1.0, rng);
        let r = random_hadamard(n, rng);
        let y = rows_matmul(&x, &r);
        for i in 0..8 {
            let nx: f32 = x.row(i).iter().map(|v| v * v).sum();
            let ny: f32 = y.row(i).iter().map(|v| v * v).sum();
            prop_close(nx, ny, 1e-2 * nx.max(1.0), "row norm preserved")?;
        }
        Ok(())
    });
}

#[test]
fn prop_blockdiag_orthogonal() {
    check(20, |rng| {
        let dh = 1usize << (2 + rng.below(3));
        let h = 1 + rng.below(4);
        let b = blockdiag_heads(&random_hadamard(dh, rng), h);
        prop_assert(orthogonality_error(&b) < 1e-3, "blockdiag orthogonal")
    });
}

#[test]
fn prop_quantizer_error_bounds() {
    check(30, |rng| {
        let s = QuantScheme {
            bits: 2 + rng.below(5) as u32,
            symmetric: true,
            clip_quantile: None,
            group: None,
        };
        let x = Tensor::randn(&[4, 64], 0.1 + rng.uniform(), rng);
        let y = fake_quant_rows(&x, &s);
        for i in 0..4 {
            let amax = x.row(i).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let step = amax.max(1e-8) / s.qmax();
            for (a, b) in x.row(i).iter().zip(y.row(i)) {
                prop_assert((a - b).abs() <= step / 2.0 + 1e-6, "sym error ≤ step/2")?;
            }
        }
        let ya = fake_quant_rows_asym(&x, &QuantScheme::kv4());
        for i in 0..4 {
            let (lo, hi) = x.row(i).iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
                (l.min(v), h.max(v))
            });
            let step = (hi - lo).max(1e-8) / 15.0;
            for (a, b) in x.row(i).iter().zip(ya.row(i)) {
                prop_assert((a - b).abs() <= step / 2.0 + 1e-5, "asym error ≤ step/2")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gptq_never_worse_than_rtn_on_hessian_metric() {
    check(10, |rng| {
        let k = 8 + rng.below(24);
        let n = 4 + rng.below(12);
        let w = Tensor::randn(&[k, n], 0.3, rng);
        let base = Tensor::randn(&[3 * k, k], 1.0, rng);
        let mix = Tensor::randn(&[k, k], 0.3, rng).add(&Tensor::eye(k));
        let h = gram(&matmul(&base, &mix));
        let s = QuantScheme::weight4();
        let eg = hessian_error(&w, &gptq_quantize(&w, &h, &s), &h);
        let er = hessian_error(&w, &rtn_quantize(&w, &s), &h);
        prop_assert(eg <= er * 1.01, "gptq ≤ rtn on tr(ΔᵀHΔ)")
    });
}

#[test]
fn prop_rotation_reduces_kurtail_loss_on_outlier_rows() {
    check(15, |rng| {
        let d = 1usize << (4 + rng.below(3));
        let mut x = Tensor::zeros(&[256, d]);
        for v in &mut x.data {
            *v = rng.laplace(1.0);
        }
        let c = rng.below(d);
        for i in 0..256 {
            x.data[i * d + c] *= 10.0 + rng.uniform() * 20.0;
        }
        let before = kurtail_loss(&x);
        let after = kurtail_loss(&rows_matmul(&x, &random_hadamard(d, rng)));
        prop_assert(after < before, "rotation lowers |κ−κ_u| on outlier data")
    });
}

#[test]
fn prop_kurtosis_invariant_to_scale_and_shift() {
    check(30, |rng| {
        let xs: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
        let k0 = kurtosis(&xs);
        let a = 0.5 + rng.uniform() * 4.0;
        let b = rng.normal() * 3.0;
        let ys: Vec<f32> = xs.iter().map(|&x| a * x + b).collect();
        prop_close(k0, kurtosis(&ys), 0.05 * k0, "κ(ax+b) = κ(x)")
    });
}

#[test]
fn prop_int4_pack_roundtrips_rtn_exactly() {
    // per-channel grids (group = None) must reproduce the RTN fake-quant
    // output bitwise at odd widths and heights
    check(25, |rng| {
        let k = 1 + rng.below(70); // covers odd k (nibble padding)
        let n = 1 + rng.below(20);
        let w = Tensor::randn(&[k, n], 0.1 + rng.uniform(), rng);
        let s = QuantScheme::weight4();
        let packed = Int4Weight::pack(&w, &s);
        let want = rtn_quantize(&w, &s);
        prop_assert(packed.unpack().data == want.data, "int4 roundtrip == rtn bitwise")
    });
}

#[test]
fn prop_int4_grouped_roundtrip_error_bounded() {
    // group-boundary shapes: group sizes that do and don't divide k
    check(20, |rng| {
        let k = 4 + rng.below(60);
        let n = 1 + rng.below(12);
        let g = 1 + rng.below(k);
        let w = Tensor::randn(&[k, n], 0.3, rng);
        let s = QuantScheme::weight4_grouped(g);
        let iw = Int4Weight::pack(&w, &s);
        prop_assert(iw.n_groups == (k + g - 1) / g, "group count")?;
        let deq = iw.unpack();
        for j in 0..n {
            for gi in 0..iw.n_groups {
                let i0 = gi * g;
                let i1 = (i0 + g).min(k);
                let amax = (i0..i1).fold(0.0f32, |a, i| a.max(w.data[i * n + j].abs()));
                let step = amax.max(1e-8) / 7.0;
                for i in i0..i1 {
                    prop_assert(
                        (deq.data[i * n + j] - w.data[i * n + j]).abs() <= step / 2.0 + 1e-6,
                        "grouped error ≤ half step",
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_int4_matmul_deterministic_and_batch_invariant() {
    check(15, |rng| {
        let k = 8 + rng.below(48);
        let n = 1 + rng.below(24);
        let m = 1 + rng.below(12);
        let g = 1 + rng.below(k);
        let w = Tensor::randn(&[k, n], 0.3, rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(g));
        let x = Tensor::randn(&[m, k], 1.0, rng);
        let base = iw.matmul_with_threads(&x, 1);
        for threads in [2usize, 8] {
            prop_assert(
                iw.matmul_with_threads(&x, threads).data == base.data,
                "int4 matmul bitwise across threads",
            )?;
        }
        // each lane of the batch equals the standalone GEMV on its row
        for i in 0..m {
            let row = Tensor::new(x.row(i).to_vec(), vec![1, k]);
            prop_assert(
                iw.matmul_with_threads(&row, 4).data == base.row(i),
                "int4 GEMV == batched lane",
            )?;
        }
        // and stays within dequantized-reference tolerance
        let want = rows_matmul(&x, &iw.unpack());
        prop_assert(base.max_abs_diff(&want) < 1e-3, "int4 matmul ≈ dense on deq")
    });
}

#[test]
fn prop_qact_codes_match_fake_quant_grid() {
    // the integer GEMM's activation codes must sit on the *exact*
    // fake_quant_rows grid: code·scale reproduces the fake-quant value
    // bitwise at odd widths, with and without the clip quantile
    check(25, |rng| {
        let m = 1 + rng.below(12);
        let k = 1 + rng.below(90); // odd widths included
        let x = Tensor::randn(&[m, k], 0.2 + rng.uniform() * 2.0, rng);
        for s in [QuantScheme::act4(), QuantScheme { clip_quantile: None, ..QuantScheme::act4() }] {
            let qa = QuantActs::quantize_with_threads(&x, &s, 1 + rng.below(8));
            let want = fake_quant_rows(&x, &s);
            prop_assert(qa.dequant().data == want.data, "code·scale == fake_quant bitwise")?;
            let qmax = s.qmax() as i32;
            prop_assert(
                qa.codes.iter().all(|&c| (c as i32).abs() <= qmax),
                "codes within ±qmax",
            )?;
            for r in 0..m {
                prop_assert(qa.scales[r] == row_scale(x.row(r), &s), "per-row scale on grid")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_int_gemm_bitwise_invariant_and_bounded_vs_f32_path() {
    // the i32-accumulator GEMM must be bitwise deterministic across
    // thread budgets and batch sizes (the serving invariants), and its
    // delta to the f32 dequant GEMM — same codes, different f32
    // summation order inside a scale group — must stay bounded
    check(15, |rng| {
        let k = 8 + rng.below(56);
        let n = 1 + rng.below(24);
        let m = 1 + rng.below(16);
        let g = 1 + rng.below(k); // group boundaries that straddle k
        let act = QuantScheme::act4();
        let w = Tensor::randn(&[k, n], 0.3, rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(g));
        let x = Tensor::randn(&[m, k], 1.0, rng);
        let base = iw.quant_matmul_with_threads(&x, &act, 1);
        for threads in [2usize, 8] {
            prop_assert(
                iw.quant_matmul_with_threads(&x, &act, threads).data == base.data,
                "int GEMM bitwise across threads",
            )?;
        }
        // lane i of the batched GEMM == the standalone integer GEMV
        for i in 0..m {
            let row = Tensor::new(x.row(i).to_vec(), vec![1, k]);
            prop_assert(
                iw.quant_matmul_with_threads(&row, &act, 4).data == base.row(i),
                "int GEMV == batched lane",
            )?;
        }
        // pre-quantized acts and the fused entry agree bitwise
        let qa = QuantActs::quantize_with_threads(&x, &act, 3);
        prop_assert(
            iw.matmul_quant_acts(&qa, 2).data == base.data,
            "shared quantized acts == fused quantize→GEMM",
        )?;
        // bounded relation to the f32 dequant path on identical codes
        let f32_path = iw.matmul(&fake_quant_rows(&x, &act));
        prop_assert(
            base.max_abs_diff(&f32_path) < 1e-4,
            "int vs f32 path delta bounded (summation order only)",
        )
    });
}

#[test]
fn prop_kv_pool_roundtrip_matches_fake_quant_asym() {
    check(15, |rng| {
        let h = 1 + rng.below(4);
        let dh = 2 + rng.below(9); // odd dh exercises nibble padding
        let bt = 1 + rng.below(6);
        let toks = 1 + rng.below(12);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 2 * (toks / bt + 1) + 2);
        let mut seq = SeqKv::new(1);
        let mut rows = Vec::new();
        for t in 0..toks {
            let k: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
            pool.append(&mut seq, 0, t, &k, &v).unwrap();
            rows.push((k, v));
        }
        for (t, (k, v)) in rows.iter().enumerate() {
            let want_k =
                fake_quant_rows_asym(&Tensor::new(k.clone(), vec![h, dh]), &QuantScheme::kv4());
            let want_v =
                fake_quant_rows_asym(&Tensor::new(v.clone(), vec![h, dh]), &QuantScheme::kv4());
            for head in 0..h {
                prop_assert(
                    pool.read_k_row(&seq, 0, t, head) == want_k.row(head),
                    "K roundtrip == fake_quant_asym bitwise",
                )?;
                prop_assert(
                    pool.read_v_row(&seq, 0, t, head) == want_v.row(head),
                    "V roundtrip == fake_quant_asym bitwise",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_serve_engine_bitwise_across_threads_and_lanes() {
    // the KV-block append/read path and every serve kernel must be
    // bitwise deterministic across KURTAIL_THREADS-style budgets and
    // independent of lane batching
    let meta = serve_test_meta();
    check(6, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let reqs: Vec<(Vec<i32>, usize)> = (0..3)
            .map(|_| {
                let p = 1 + rng.below(4);
                let toks = (0..p).map(|_| rng.below(meta.vocab) as i32).collect();
                (toks, 1 + rng.below(5))
            })
            .collect();
        let run = |lanes: usize, threads: usize| -> Vec<Vec<i32>> {
            let cfg = ServeConfig {
                max_lanes: lanes,
                block_tokens: 2,
                kv_quant: KvQuant::Asym4,
                threads: Some(threads),
                ..ServeConfig::default()
            };
            let mut eng = Engine::new(model.clone(), &cfg).unwrap();
            for (toks, n) in &reqs {
                eng.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
            }
            eng.run().unwrap().into_iter().map(|c| c.tokens).collect()
        };
        let base = run(1, 1);
        for (lanes, threads) in [(1usize, 8usize), (3, 1), (3, 8)] {
            prop_assert(
                run(lanes, threads) == base,
                &format!("serve streams bitwise at lanes={lanes} threads={threads}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_panel_cache_bitwise_transparent() {
    // the i8 panel cache is a layout change only: every GEMM entry
    // (f32 dequant + integer, GEMV + batched) must produce identical
    // bits with the cache built and without, at every thread budget
    check(15, |rng| {
        let k = 4 + rng.below(60);
        let n = 1 + rng.below(20);
        let m = 1 + rng.below(12);
        let g = 1 + rng.below(k);
        let act = QuantScheme::act4();
        let w = Tensor::randn(&[k, n], 0.3, rng);
        let cold = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(g));
        let mut hot = cold.clone();
        hot.build_panels();
        let x = Tensor::randn(&[m, k], 1.0, rng);
        for threads in [1usize, 4] {
            prop_assert(
                hot.matmul_with_threads(&x, threads).data
                    == cold.matmul_with_threads(&x, threads).data,
                "panel cache transparent on the f32 dequant GEMM",
            )?;
            prop_assert(
                hot.quant_matmul_with_threads(&x, &act, threads).data
                    == cold.quant_matmul_with_threads(&x, &act, threads).data,
                "panel cache transparent on the integer GEMM",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_serve_arena_and_panel_streams_bitwise() {
    // the scratch arena and the panel cache must be bitwise invisible:
    // decode streams with (fresh-alloc, no panels) — the PR-3 profile —
    // equal every (arena, panel) combination across KURTAIL_THREADS-style
    // budgets {1, 4} and lanes {1, 16}, on both GEMM paths
    let meta = serve_test_meta();
    check(4, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let reqs: Vec<(Vec<i32>, usize)> = (0..3)
            .map(|_| {
                let p = 1 + rng.below(4);
                let toks = (0..p).map(|_| rng.below(meta.vocab) as i32).collect();
                (toks, 1 + rng.below(5))
            })
            .collect();
        for int_gemm in [true, false] {
            let run = |lanes: usize, threads: usize, arena: bool, panel: usize| -> Vec<Vec<i32>> {
                let cfg = ServeConfig {
                    max_lanes: lanes,
                    block_tokens: 2,
                    kv_quant: KvQuant::Asym4,
                    threads: Some(threads),
                    int_gemm: Some(int_gemm),
                    arena: Some(arena),
                    panel_cache: Some(panel),
                    ..ServeConfig::default()
                };
                let mut eng = Engine::new(model.clone(), &cfg).unwrap();
                for (toks, n) in &reqs {
                    eng.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
                }
                eng.run().unwrap().into_iter().map(|c| c.tokens).collect()
            };
            // PR-3 profile: fresh allocations, no panel cache
            let base = run(1, 1, false, 0);
            for (lanes, threads) in [(1usize, 4usize), (16, 1), (16, 4)] {
                for (arena, panel) in [(true, 0), (true, usize::MAX), (false, usize::MAX)] {
                    prop_assert(
                        run(lanes, threads, arena, panel) == base,
                        &format!(
                            "serve streams bitwise at lanes={lanes} threads={threads} \
                             arena={arena} panel={panel} int={int_gemm}"
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_serve_streams_bitwise_across_backends_and_layouts() {
    // the work-stealing runtime and the fused column-major epilogues
    // are performance knobs only: streams with the static backend and
    // the PR-4 serial-flip epilogue — at one lane, one thread — must
    // equal every {backend} × {epilogue} × {threads 1,4,8} × {lanes
    // 1,16} combination, on both GEMM paths
    let meta = serve_test_meta();
    check(3, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let reqs: Vec<(Vec<i32>, usize)> = (0..3)
            .map(|_| {
                let p = 1 + rng.below(4);
                let toks = (0..p).map(|_| rng.below(meta.vocab) as i32).collect();
                (toks, 1 + rng.below(5))
            })
            .collect();
        for int_gemm in [true, false] {
            let run = |lanes: usize, threads: usize, backend: ParBackend, fused: bool| -> Vec<Vec<i32>> {
                let cfg = ServeConfig {
                    max_lanes: lanes,
                    block_tokens: 2,
                    kv_quant: KvQuant::Asym4,
                    threads: Some(threads),
                    int_gemm: Some(int_gemm),
                    arena: Some(true),
                    par_backend: Some(backend),
                    fused_epilogue: Some(fused),
                    ..ServeConfig::default()
                };
                let mut eng = Engine::new(model.clone(), &cfg).unwrap();
                for (toks, n) in &reqs {
                    eng.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
                }
                eng.run().unwrap().into_iter().map(|c| c.tokens).collect()
            };
            let base = run(1, 1, ParBackend::Static, false);
            for backend in [ParBackend::Static, ParBackend::Steal] {
                for fused in [false, true] {
                    for (lanes, threads) in [(1usize, 4usize), (16, 1), (16, 8)] {
                        prop_assert(
                            run(lanes, threads, backend, fused) == base,
                            &format!(
                                "serve streams bitwise at lanes={lanes} threads={threads} \
                                 {backend:?} fused={fused} int={int_gemm}"
                            ),
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cancel_interleavings_leak_free_and_replayable() {
    // the daemon's fault-tolerance invariant: after ANY interleaving of
    // admit / mid-flight cancel / EOS retire / drain, (a) the pool is
    // whole (free == max, committed == 0), (b) every surviving stream
    // is bitwise identical to an undisturbed run of the same
    // submissions, and (c) when no drain fired, resubmitting the
    // identical workload on the SAME engine replays bitwise — the
    // interleaving did not poison later admissions
    let meta = serve_test_meta();
    check(6, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            ..ServeConfig::default()
        };
        // temp 0 everywhere: argmax sampling is id-independent, so the
        // same workload replays bitwise even at fresh request ids
        let reqs: Vec<(Vec<i32>, usize)> = (0..4)
            .map(|_| {
                let p = 1 + rng.below(3);
                let toks = (0..p).map(|_| rng.below(meta.vocab) as i32).collect();
                (toks, 1 + rng.below(4))
            })
            .collect();
        // probe (no stop) to learn the streams, then give one request a
        // stop token that provably fires (its first generated token) so
        // the interleaving includes an EOS retire
        let mut probe = Engine::new(model.clone(), &cfg).unwrap();
        for (toks, n) in &reqs {
            probe.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
        }
        let mut probed = probe.run().unwrap();
        probed.sort_by_key(|c| c.id);
        let eos_req = rng.below(reqs.len());
        let stop_of = |i: usize| -> Option<i32> {
            if i == eos_req {
                Some(probed[i].tokens[probed[i].prompt_len])
            } else {
                None
            }
        };

        // undisturbed reference with the stop in place
        let mut reference = Engine::new(model.clone(), &cfg).unwrap();
        for (i, (toks, n)) in reqs.iter().enumerate() {
            reference.submit_tokens_stop(toks.clone(), *n, 0.0, 3, stop_of(i)).unwrap();
        }
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        // interleaved run: random cancel schedule, maybe a drain
        let mut eng = Engine::new(model.clone(), &cfg).unwrap();
        let ids: Vec<usize> = reqs
            .iter()
            .enumerate()
            .map(|(i, (toks, n))| eng.submit_tokens_stop(toks.clone(), *n, 0.0, 3, stop_of(i)).unwrap())
            .collect();
        let cancel_at: Vec<Option<usize>> =
            ids.iter().map(|_| (rng.below(3) == 0).then(|| rng.below(6))).collect();
        let drain_at = (rng.below(3) == 0).then(|| rng.below(4));
        let mut gone: HashSet<usize> = HashSet::new();
        let mut step_n = 0usize;
        loop {
            for (i, id) in ids.iter().enumerate() {
                if cancel_at[i] == Some(step_n) && eng.cancel(*id) {
                    gone.insert(*id);
                }
            }
            if drain_at == Some(step_n) {
                for id in eng.begin_drain() {
                    gone.insert(id);
                }
            }
            if !eng.step().unwrap() {
                break;
            }
            step_n += 1;
        }
        let done = eng.take_completions();

        // (a) leak-freedom, whatever the interleaving did
        prop_assert(
            eng.pool().free_blocks() == eng.pool().max_blocks && eng.committed_blocks() == 0,
            &format!("pool whole after interleaving (cancels={cancel_at:?} drain={drain_at:?})"),
        )?;
        // (b) survivors are exactly the un-gone requests, bitwise equal
        prop_assert(done.len() == ids.len() - gone.len(), "survivors = submissions - cancels - shed")?;
        for c in &done {
            prop_assert(!gone.contains(&c.id), "a canceled/shed request must not complete")?;
            prop_assert(
                c.tokens == want[c.id].tokens,
                &format!("surviving stream {} bitwise equal to undisturbed run", c.id),
            )?;
        }
        if drain_at.is_some() {
            prop_assert(
                matches!(eng.submit_tokens(vec![1], 1, 0.0, 1), Err(ServeError::Draining)),
                "post-drain submits shed with Draining",
            )?;
        } else {
            // (c) identical round 2 on the SAME engine replays bitwise
            for (i, (toks, n)) in reqs.iter().enumerate() {
                eng.submit_tokens_stop(toks.clone(), *n, 0.0, 3, stop_of(i)).unwrap();
            }
            let mut done2 = eng.run().unwrap();
            done2.sort_by_key(|c| c.id);
            prop_assert(done2.len() == reqs.len(), "round 2 completes everything")?;
            for (k, c) in done2.iter().enumerate() {
                prop_assert(c.tokens == want[k].tokens, &format!("round-2 stream {k} replays bitwise"))?;
            }
            prop_assert(
                eng.pool().free_blocks() == eng.pool().max_blocks,
                "pool whole again after round 2",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_shared_prefix_cancel_interleavings_leak_free_and_bitwise() {
    // the PR-6 leak-free invariant extended to refcounted shared
    // blocks: N lanes admitted over one shared prompt prefix (full
    // blocks by refcount bump, tails copy-on-write), then cancel / EOS
    // / drain in random order. Afterwards (a) the pool is whole and no
    // shared reference survives, (b) every surviving stream is bitwise
    // the stream of a sharing-OFF undisturbed run of the same schedule,
    // (c) replaying the workload on the same engine reproduces it
    let meta = serve_test_meta();
    check(6, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let mk_cfg = |share: bool| ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            prefix_share: Some(share),
            ..ServeConfig::default()
        };
        // one shared 3-token prefix; distinct suffixes so COW tails (an
        // odd prefix against block_tokens 2) are exercised too
        let prefix: Vec<i32> = (0..3).map(|_| rng.below(meta.vocab) as i32).collect();
        let reqs: Vec<(Vec<i32>, usize)> = (0..4)
            .map(|i| {
                let mut toks = prefix.clone();
                toks.push(i as i32);
                (toks, 1 + rng.below(4))
            })
            .collect();
        // the donor must finish prefill before the sharers are admitted
        // (sharing is discovered at admission), so the schedule is:
        // submit req 0, one step, submit the rest, run. Identical for
        // every engine below, so streams are comparable bitwise.
        let submit_all = |eng: &mut Engine, stops: &dyn Fn(usize) -> Option<i32>| -> Vec<usize> {
            let mut ids = Vec::new();
            for (i, (toks, n)) in reqs.iter().enumerate() {
                ids.push(eng.submit_tokens_stop(toks.clone(), *n, 0.0, 3, stops(i)).unwrap());
                if i == 0 {
                    eng.step().unwrap();
                }
            }
            ids
        };
        // probe to learn the streams, then give one request a stop
        // token that provably fires so EOS retires join the interleaving
        let mut probe = Engine::new(model.clone(), &mk_cfg(true)).unwrap();
        submit_all(&mut probe, &|_| None);
        let mut probed = probe.run().unwrap();
        probed.sort_by_key(|c| c.id);
        let eos_req = rng.below(reqs.len());
        let stop_of = move |i: usize| -> Option<i32> {
            (i == eos_req).then(|| probed[i].tokens[probed[i].prompt_len])
        };

        // undisturbed, sharing OFF: the ground truth the shared runs
        // must reproduce bitwise
        let mut reference = Engine::new(model.clone(), &mk_cfg(false)).unwrap();
        submit_all(&mut reference, &stop_of);
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        let mut eng = Engine::new(model.clone(), &mk_cfg(true)).unwrap();
        let ids = submit_all(&mut eng, &stop_of);
        let cancel_at: Vec<Option<usize>> =
            ids.iter().map(|_| (rng.below(3) == 0).then(|| rng.below(6))).collect();
        let drain_at = (rng.below(4) == 0).then(|| rng.below(4));
        let mut gone: HashSet<usize> = HashSet::new();
        let mut step_n = 0usize;
        loop {
            for (i, id) in ids.iter().enumerate() {
                if cancel_at[i] == Some(step_n) && eng.cancel(*id) {
                    gone.insert(*id);
                }
            }
            if drain_at == Some(step_n) {
                for id in eng.begin_drain() {
                    gone.insert(id);
                }
            }
            if !eng.step().unwrap() {
                break;
            }
            step_n += 1;
        }
        let done = eng.take_completions();
        prop_assert(
            eng.pool().free_blocks() == eng.pool().max_blocks
                && eng.committed_blocks() == 0
                && eng.shared_block_refs() == 0,
            &format!(
                "pool whole, no shared refs after interleaving \
                 (cancels={cancel_at:?} drain={drain_at:?})"
            ),
        )?;
        prop_assert(done.len() == ids.len() - gone.len(), "survivors = submissions - cancels - shed")?;
        for c in &done {
            prop_assert(!gone.contains(&c.id), "a canceled/shed request must not complete")?;
            prop_assert(
                c.tokens == want[c.id].tokens,
                &format!("shared stream {} bitwise equal to the sharing-off run", c.id),
            )?;
        }
        if drain_at.is_none() {
            // replay the same schedule on the SAME engine: refcounted
            // release + index invalidation left no stale state behind
            let ids2 = submit_all(&mut eng, &stop_of);
            let mut done2 = eng.run().unwrap();
            done2.sort_by_key(|c| c.id);
            prop_assert(done2.len() == reqs.len(), "round 2 completes everything")?;
            for (k, c) in done2.iter().enumerate() {
                prop_assert(c.id == ids2[k], "round-2 ids in submission order")?;
                prop_assert(c.tokens == want[k].tokens, &format!("round-2 stream {k} replays bitwise"))?;
            }
            prop_assert(
                eng.pool().free_blocks() == eng.pool().max_blocks && eng.shared_block_refs() == 0,
                "pool whole again after round 2",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_sharing_and_chunking_bitwise_across_backends_and_layouts() {
    // prefix sharing and chunked prefill are memory/latency knobs only:
    // a sharing-off, unchunked, static-backend, serial-flip run is the
    // reference, and every {share} × {chunk} × {backend} × {epilogue} ×
    // {lanes, threads} combination must reproduce its token streams
    // bitwise, on both GEMM paths
    let meta = serve_test_meta();
    check(3, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let prefix: Vec<i32> = (0..3).map(|_| rng.below(meta.vocab) as i32).collect();
        let reqs: Vec<(Vec<i32>, usize)> = (0..4)
            .map(|i| {
                let mut toks = prefix.clone();
                toks.push(i as i32);
                (toks, 1 + rng.below(4))
            })
            .collect();
        for int_gemm in [true, false] {
            let run = |lanes: usize,
                       threads: usize,
                       backend: ParBackend,
                       fused: bool,
                       share: bool,
                       chunk: usize|
             -> Vec<Vec<i32>> {
                let cfg = ServeConfig {
                    max_lanes: lanes,
                    block_tokens: 2,
                    kv_quant: KvQuant::Asym4,
                    threads: Some(threads),
                    int_gemm: Some(int_gemm),
                    arena: Some(true),
                    par_backend: Some(backend),
                    fused_epilogue: Some(fused),
                    prefix_share: Some(share),
                    prefill_chunk: Some(chunk),
                    ..ServeConfig::default()
                };
                let mut eng = Engine::new(model.clone(), &cfg).unwrap();
                // step after the first submit so later admissions can
                // actually share the donor's registered prefix
                for (i, (toks, n)) in reqs.iter().enumerate() {
                    eng.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
                    if i == 0 {
                        eng.step().unwrap();
                    }
                }
                let mut done = eng.run().unwrap();
                done.sort_by_key(|c| c.id);
                assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
                done.into_iter().map(|c| c.tokens).collect()
            };
            let base = run(1, 1, ParBackend::Static, false, false, 0);
            for backend in [ParBackend::Static, ParBackend::Steal] {
                for fused in [false, true] {
                    for (share, chunk) in [(true, 0), (true, 2), (false, 1)] {
                        for (lanes, threads) in [(4usize, 1usize), (4, 8)] {
                            prop_assert(
                                run(lanes, threads, backend, fused, share, chunk) == base,
                                &format!(
                                    "streams bitwise at lanes={lanes} threads={threads} \
                                     {backend:?} fused={fused} share={share} chunk={chunk} \
                                     int={int_gemm}"
                                ),
                            )?;
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_quantile_brackets_true_order_statistic() {
    // the log2-bucket estimate is the upper bound of the bucket holding
    // rank ceil(q·count): always ≥ the true order statistic and < 2× it
    // (values stay below the overflow bucket, where the bound is by
    // construction unavailable)
    check(25, |rng| {
        let h = Histogram::new();
        let n = 1 + rng.below(400);
        let mut values: Vec<u64> = (0..n)
            .map(|_| {
                // spread draws across bucket magnitudes 0..2^41, zeros included
                let mag = rng.below(41) as u32;
                rng.next_u64() % (1u64 << (mag + 1))
            })
            .collect();
        for &v in &values {
            h.record_ns(v);
        }
        values.sort_unstable();
        let s = h.snapshot();
        prop_assert(s.count == n as u64, "count == recorded")?;
        prop_assert(s.sum_ns == values.iter().sum::<u64>(), "sum == recorded")?;
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let est = s.quantile_ns(q).unwrap();
            let rank = ((q * n as f64).ceil() as usize).max(1);
            let truth = values[rank - 1];
            prop_assert(
                est >= truth && est < 2 * truth.max(1),
                &format!("q={q}: estimate {est} brackets true {truth} (n={n})"),
            )?;
        }
        prop_assert(Histogram::new().snapshot().quantile_ns(0.5).is_none(), "empty → None")
    });
}

#[test]
fn prop_histogram_merge_associative_and_lossless() {
    // shard merges must be order-independent (associative + commutative)
    // and must reproduce the histogram a single writer would have built
    // from the union of the observations
    check(25, |rng| {
        let whole = Histogram::new();
        let shards: Vec<Histogram> = (0..3).map(|_| Histogram::new()).collect();
        for _ in 0..rng.below(300) {
            let v = rng.next_u64() % (1u64 << (1 + rng.below(42)));
            whole.record_ns(v);
            shards[rng.below(3)].record_ns(v);
        }
        let [a, b, c] = [shards[0].snapshot(), shards[1].snapshot(), shards[2].snapshot()];

        let mut left = a; // (a ⊕ b) ⊕ c
        left.merge(&b);
        left.merge(&c);
        let mut right = c; // a ⊕ (c ⊕ b), snapshots are Copy
        right.merge(&b);
        let mut swapped = a;
        swapped.merge(&right);

        prop_assert(left == swapped, "merge order-independent")?;
        prop_assert(left == whole.snapshot(), "sharded == single-writer")?;
        prop_assert(
            left.mean_ns() == whole.snapshot().mean_ns(),
            "mean survives the merge exactly",
        )
    });
}

#[test]
fn prop_tokenizer_batching_roundtrip() {
    check(25, |rng| {
        let world = World::generate(rng.next_u64());
        let text = corpus::training_corpus(&world, 4_000, rng.next_u64());
        let ds = TokenDataset::from_text(&text, 32);
        prop_assert(ds.n_sequences() > 0, "non-empty dataset")?;
        let idx: Vec<usize> = (0..4.min(ds.n_sequences())).collect();
        let batch = ds.batch(&idx);
        // batch rows decode back to the original text slices
        let tok = ByteTokenizer;
        for (row, &i) in idx.iter().enumerate() {
            let got = tok.decode(&batch.data[row * 32..(row + 1) * 32]);
            let want = tok.decode(ds.sequence(i));
            prop_assert(got == want, "batch row matches sequence")?;
        }
        Ok(())
    });
}

#[test]
fn prop_corpus_kinds_deterministic_and_distinct() {
    check(10, |rng| {
        let seed = rng.next_u64();
        for kind in CorpusKind::all() {
            let a = corpus::generate(kind, 2_000, seed);
            let b = corpus::generate(kind, 2_000, seed);
            prop_assert(a == b, "corpus deterministic")?;
        }
        let w = corpus::generate(CorpusKind::Wiki, 2_000, seed);
        let p = corpus::generate(CorpusKind::Ptb, 2_000, seed);
        prop_assert(w != p, "kinds differ")
    });
}

#[test]
fn prop_reload_priority_interleavings_leak_free_and_bitwise() {
    // the PR-9 overload-resilience invariant: ANY interleaving of
    // priority-classed admissions, live config reloads (tenant caps,
    // policies, fault timing) and queue evictions (a) leaves the pool
    // whole, (b) never drops an in-flight stream mid-flight, and (c)
    // completes every surviving request bitwise identical to an
    // undisturbed run of the same prompts (temp 0: argmax sampling is
    // id- and batch-independent)
    let meta = serve_test_meta();
    check(4, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            queue_cap: 3, // small enough that priority evictions happen
            ..ServeConfig::default()
        };
        let reqs: Vec<(Vec<i32>, usize)> = (0..6)
            .map(|_| {
                let p = 1 + rng.below(3);
                let toks = (0..p).map(|_| rng.below(meta.vocab) as i32).collect();
                (toks, 1 + rng.below(4))
            })
            .collect();
        // undisturbed reference: a lane's stream does not depend on its
        // batch-mates, so one run of all six yields each prompt's
        // canonical stream, indexable by submission order
        let mut reference = Engine::new(model.clone(), &cfg).unwrap();
        for (toks, n) in &reqs {
            reference.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
        }
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        let mk_runtime = |rng: &mut Rng| -> RuntimeConfig {
            let mut tenants = BTreeMap::new();
            tenants.insert(
                "hi".to_string(),
                TenantPolicy { priority: Priority::High, ..TenantPolicy::default() },
            );
            tenants.insert(
                "lo".to_string(),
                TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() },
            );
            RuntimeConfig {
                per_tenant_cap: rng.below(3), // 0 = unlimited, or 1..2
                tenants,
                fault: FaultSpec { slow_step_ms: rng.below(2) as u64, ..FaultSpec::none() },
                ..RuntimeConfig::default()
            }
        };
        let cell = Arc::new(ConfigCell::new(mk_runtime(rng)));
        let engine = Engine::new(model.clone(), &cfg).unwrap();
        let (host, handle) = spawn_host_reloadable(engine, Arc::clone(&cell));
        let tenant_names = ["hi", "lo", "mid"]; // mid = default (Normal)
        let mut rxs = Vec::new();
        for (i, (toks, n)) in reqs.iter().enumerate() {
            if rng.below(2) == 0 {
                cell.install(mk_runtime(rng)); // live reload mid-workload
            }
            let (tx, rx) = mpsc::channel();
            let res = host.submit(SubmitReq {
                tokens: toks.clone(),
                n_tokens: *n,
                temp: 0.0,
                seed: 3,
                stop: None,
                tenant: tenant_names[rng.below(3)].to_string(),
                deadline: None,
                events: tx,
            });
            rxs.push((i, rx, res));
        }
        for (i, rx, res) in rxs {
            match res {
                Err(e) => prop_assert(
                    matches!(e, ServeError::QueueFull { .. } | ServeError::RateLimited { .. }),
                    &format!("admission shed {i} is a typed backpressure error, got {e:?}"),
                )?,
                Ok(_) => {
                    let mut toks = Vec::new();
                    loop {
                        match rx.recv_timeout(Duration::from_secs(20)) {
                            Ok(Event::Token(t)) => toks.push(t),
                            Ok(Event::Done(c)) => {
                                prop_assert(
                                    c.tokens == want[i].tokens,
                                    &format!("completion {i} bitwise equals the undisturbed run"),
                                )?;
                                prop_assert(
                                    toks == want[i].tokens[want[i].prompt_len..],
                                    &format!("stream {i} == generated suffix"),
                                )?;
                                break;
                            }
                            Ok(Event::Failed(e)) => {
                                // the only legitimate in-flight failure
                                // here is a priority eviction; reloads
                                // must never kill a stream
                                prop_assert(
                                    matches!(e, ServeError::QueueFull { .. }),
                                    &format!("in-flight failure {i} is an eviction, got {e:?}"),
                                )?;
                                prop_assert(
                                    toks.is_empty(),
                                    &format!("evicted request {i} was queued, never streaming"),
                                )?;
                                break;
                            }
                            Err(_) => {
                                prop_assert(false, &format!("request {i}: engine thread hung"))?;
                                break;
                            }
                        }
                    }
                }
            }
        }
        let stats = host.stats().expect("host alive");
        prop_assert(
            stats.free_blocks == stats.max_blocks,
            "pool whole after reload/priority interleaving",
        )?;
        host.drain();
        handle.join().expect("engine thread exits clean");
        Ok(())
    });
}

#[test]
fn prop_preemption_interleavings_leak_free_and_bitwise() {
    // the PR-10 graceful-degradation invariant: for ANY schedule of
    // KV-pressure preemptions, cancels and drains over shared-prefix
    // lanes of mixed priority, (a) the pool ends whole with zero shared
    // refs, and (b) every completed stream is bitwise the stream of an
    // undisturbed run on a roomy pool with preemption off — preemption
    // moves *when* tokens are computed, never *which* tokens
    let meta = serve_test_meta();
    check(5, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        // tight pool: each lane reserves 2 layers × 2 (K,V) ×
        // ceil(6/2) = 12 blocks, so two live lanes commit 24/26 — past
        // the 0.85 watermark — and a queued higher-class request can
        // only seat by preempting a lower-class lane
        let tight = ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            max_blocks: 26,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            preempt: Some(true),
            ..ServeConfig::default()
        };
        let roomy = ServeConfig {
            max_blocks: 0, // auto-sized: never under pressure
            preempt: Some(false),
            ..tight.clone()
        };
        // one shared 3-token prefix (odd against block_tokens 2, so COW
        // tails are in play); class order Low, Normal first so at least
        // one later arrival outranks a seated lane
        let prefix: Vec<i32> = (0..3).map(|_| rng.below(meta.vocab) as i32).collect();
        let classes = [Priority::Low, Priority::Normal, Priority::High];
        let reqs: Vec<(Vec<i32>, usize, Priority)> = (0..4)
            .map(|i| {
                let mut toks = prefix.clone();
                toks.push(i as i32);
                let class = match i {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => classes[rng.below(3)],
                };
                (toks, 1 + rng.below(2), class)
            })
            .collect();
        // the donor must finish prefill before sharers admit, so every
        // engine runs the same schedule: submit 0, one step, the rest
        let submit_all = |eng: &mut Engine| -> Vec<usize> {
            let mut ids = Vec::new();
            for (i, (toks, n, class)) in reqs.iter().enumerate() {
                ids.push(eng.submit_tokens_prio(toks.clone(), *n, 0.0, 3, None, *class).unwrap());
                if i == 0 {
                    eng.step().unwrap();
                }
            }
            ids
        };
        // ground truth: roomy pool, preemption off, temp 0 (argmax is
        // id- and batch-independent, so streams are comparable)
        let mut reference = Engine::new(model.clone(), &roomy).unwrap();
        submit_all(&mut reference);
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        let mut eng = Engine::new(model.clone(), &tight).unwrap();
        let ids = submit_all(&mut eng);
        let cancel_at: Vec<Option<usize>> =
            ids.iter().map(|_| (rng.below(3) == 0).then(|| rng.below(6))).collect();
        let drain_at = (rng.below(4) == 0).then(|| rng.below(4));
        let mut gone: HashSet<usize> = HashSet::new();
        let mut step_n = 0usize;
        loop {
            for (i, id) in ids.iter().enumerate() {
                if cancel_at[i] == Some(step_n) && eng.cancel(*id) {
                    gone.insert(*id);
                }
            }
            if drain_at == Some(step_n) {
                // drain sheds only fresh queued requests; preempted
                // lanes are morally in-flight and must still finish
                for id in eng.begin_drain() {
                    gone.insert(id);
                }
            }
            if !eng.step().unwrap() {
                break;
            }
            step_n += 1;
        }
        let done = eng.take_completions();
        prop_assert(
            eng.pool().free_blocks() == eng.pool().max_blocks
                && eng.committed_blocks() == 0
                && eng.shared_block_refs() == 0,
            &format!(
                "pool whole, no shared refs after preemption interleaving \
                 (preempted={} cancels={cancel_at:?} drain={drain_at:?})",
                eng.stats.preempted
            ),
        )?;
        prop_assert(
            eng.stats.resumed <= eng.stats.preempted,
            "every resume traces back to a preemption",
        )?;
        prop_assert(done.len() == ids.len() - gone.len(), "survivors = submissions - cancels - shed")?;
        for c in &done {
            prop_assert(!gone.contains(&c.id), "a canceled/shed request must not complete")?;
            prop_assert(
                c.tokens == want[c.id].tokens,
                &format!(
                    "preempted/resumed stream {} bitwise equal to the undisturbed roomy run",
                    c.id
                ),
            )?;
        }
        if drain_at.is_none() {
            // replay the same workload on the SAME engine: preemption
            // snapshots left no stale scheduler or pool state behind
            let ids2 = submit_all(&mut eng);
            let mut done2 = eng.run().unwrap();
            done2.sort_by_key(|c| c.id);
            prop_assert(done2.len() == reqs.len(), "round 2 completes everything")?;
            for (k, c) in done2.iter().enumerate() {
                prop_assert(c.id == ids2[k], "round-2 ids in submission order")?;
                prop_assert(c.tokens == want[k].tokens, &format!("round-2 stream {k} replays bitwise"))?;
            }
            prop_assert(
                eng.pool().free_blocks() == eng.pool().max_blocks && eng.shared_block_refs() == 0,
                "pool whole again after round 2",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_engine_panic_resume_completes_every_stream_bitwise() {
    // host-level transparent resume: a one-shot injected engine panic
    // lands at a seeded-random step — before any token, mid-stream, or
    // never — and must be invisible to clients: no stream fails, every
    // completion is bitwise the undisturbed run, every generated token
    // is streamed exactly once, and the pool comes back whole
    let meta = serve_test_meta();
    check(4, |rng| {
        let params = Params::init(&meta, &mut rng.fork(1));
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_head, rng),
            random_hadamard(meta.d_ff, rng),
        );
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let scfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            ..ServeConfig::default()
        };
        let prefix: Vec<i32> = (0..3).map(|_| rng.below(meta.vocab) as i32).collect();
        let reqs: Vec<(Vec<i32>, usize)> = (0..3)
            .map(|i| {
                let mut toks = prefix.clone();
                toks.push(i as i32);
                (toks, 2 + rng.below(3))
            })
            .collect();
        let mut reference = Engine::new(model.clone(), &scfg).unwrap();
        for (toks, n) in &reqs {
            reference.submit_tokens(toks.clone(), *n, 0.0, 3).unwrap();
        }
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        // seeded panic timing: p=0.4 per step, one-shot, so a random
        // seed places the (at most one) restart anywhere in the run
        let fault = FaultSpec {
            engine_panic: 0.4,
            seed: rng.next_u64(),
            ..FaultSpec::none()
        };
        let cell = Arc::new(ConfigCell::new(RuntimeConfig { fault, ..RuntimeConfig::default() }));
        let engine = Engine::new(model.clone(), &scfg).unwrap();
        let (host, handle) = spawn_host_supervised(engine, Arc::clone(&cell), scfg.clone());
        let mut rxs = Vec::new();
        for (toks, n) in &reqs {
            let (tx, rx) = mpsc::channel();
            host.submit(SubmitReq {
                tokens: toks.clone(),
                n_tokens: *n,
                temp: 0.0,
                seed: 3,
                stop: None,
                tenant: "t".to_string(),
                deadline: None,
                events: tx,
            })
            .expect("admission under supervision");
            rxs.push(rx);
        }
        for (i, rx) in rxs.iter().enumerate() {
            let mut toks = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(Event::Token(t)) => toks.push(t),
                    Ok(Event::Done(c)) => {
                        prop_assert(
                            c.tokens == want[i].tokens,
                            &format!("stream {i} bitwise equals the undisturbed run"),
                        )?;
                        prop_assert(
                            toks == want[i].tokens[want[i].prompt_len..],
                            &format!("stream {i}: every token streamed exactly once"),
                        )?;
                        break;
                    }
                    Ok(Event::Failed(e)) => {
                        prop_assert(false, &format!("stream {i} failed across restart: {e:?}"))?;
                        break;
                    }
                    Err(_) => {
                        prop_assert(false, &format!("stream {i}: engine thread hung"))?;
                        break;
                    }
                }
            }
        }
        let stats = host.stats().expect("host alive");
        prop_assert(
            stats.free_blocks == stats.max_blocks,
            &format!("pool whole after {} restart(s)", stats.engine_restarts),
        )?;
        prop_assert(
            stats.engine_restarts <= 1,
            "the injected panic is one-shot: at most one restart",
        )?;
        host.drain();
        handle.join().expect("engine thread exits clean");
        Ok(())
    });
}
