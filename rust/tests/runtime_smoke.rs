//! Integration: the AOT artifacts actually load, compile and execute on
//! the Rust PJRT CPU client with correct numerics. This is the keystone
//! test of the three-layer architecture — everything else builds on it.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use kurtail::runtime::{Runtime, Value};
use kurtail::tensor::{hadamard, stats, IntTensor, Tensor};
use kurtail::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

/// Random params in manifest order (init semantics match model::init).
fn random_params(rt: &Runtime, cfg: &str, rng: &mut Rng) -> Vec<Value> {
    let meta = rt.manifest.config(cfg).unwrap();
    meta.param_specs
        .iter()
        .map(|p| {
            if p.name.starts_with("ln") {
                Value::F32(Tensor::ones(&p.shape))
            } else {
                let fan_in = if p.shape.len() >= 2 { p.shape[p.shape.len() - 2] } else { 64 };
                let std = if p.name == "embed" || p.name == "head" {
                    0.02
                } else {
                    1.0 / (fan_in as f32).sqrt()
                };
                Value::F32(Tensor::randn(&p.shape, std, rng))
            }
        })
        .collect()
}

fn random_tokens(vocab: usize, b: usize, t: usize, rng: &mut Rng) -> IntTensor {
    IntTensor::new((0..b * t).map(|_| rng.below(vocab) as i32).collect(), vec![b, t])
}

#[test]
fn fwd_nll_fp_and_quant_execute() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0);
    let meta = rt.manifest.config("tiny").unwrap().clone();
    let params = random_params(&rt, "tiny", &mut rng);
    let (b, t) = (meta.eval_batch, meta.seq_len);
    let tokens = random_tokens(meta.vocab, b, t, &mut rng);
    let mask = Tensor::ones(&[b, t]);

    // fp
    let art = rt.load("fwd_nll_tiny").expect("load fwd_nll_tiny");
    let mut inputs = params.clone();
    inputs.push(tokens.clone().into());
    inputs.push(mask.clone().into());
    let out = art.run(&inputs).expect("run fwd_nll_tiny");
    let nll = out[0].as_f32().unwrap();
    let cnt = out[1].as_f32().unwrap();
    assert!(nll.all_finite() && nll.data.iter().all(|&x| x > 0.0));
    assert_eq!(cnt.data[0], (t - 1) as f32);
    // random init ⇒ per-token NLL ≈ ln(vocab)
    let per_tok = nll.data[0] / cnt.data[0];
    assert!((per_tok - (meta.vocab as f32).ln()).abs() < 1.0, "per_tok={per_tok}");

    // quant (exercises the Pallas quant_matmul path inside the graph)
    let art_q = rt.load("fwd_nll_quant_tiny").expect("load fwd_nll_quant_tiny");
    let mut inputs_q = params.clone();
    inputs_q.push(Tensor::eye(meta.d_head).into());
    inputs_q.push(Tensor::eye(meta.d_head).into());
    inputs_q.push(Tensor::eye(meta.d_ff).into());
    inputs_q.push(tokens.into());
    inputs_q.push(mask.into());
    let out_q = art_q.run(&inputs_q).expect("run fwd_nll_quant_tiny");
    let nll_q = out_q[0].as_f32().unwrap();
    assert!(nll_q.all_finite());
    let per_tok_q = nll_q.data[0] / cnt.data[0];
    assert!((per_tok_q - per_tok).abs() < 1.5, "quant {per_tok_q} vs fp {per_tok}");
}

#[test]
fn train_step_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let meta = rt.manifest.config("tiny").unwrap().clone();
    let mut params = random_params(&rt, "tiny", &mut rng);
    let n = params.len();
    let mut m: Vec<Value> = meta.param_specs.iter().map(|p| Tensor::zeros(&p.shape).into()).collect();
    let mut v = m.clone();
    // repetitive data is easy to learn fast
    let (b, t) = (meta.train_batch, meta.seq_len);
    let tokens = IntTensor::new(
        (0..b * t).map(|i| if i % 2 == 0 { 3 } else { 7 }).collect(),
        vec![b, t],
    );

    let art = rt.load("train_step_tiny").expect("load");
    let mut losses = Vec::new();
    for step in 1..=8 {
        let mut inputs: Vec<Value> = Vec::with_capacity(3 * n + 3);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(tokens.clone().into());
        inputs.push(Value::from(3e-3f32));
        inputs.push(Value::from(step as f32));
        let out = art.run(&inputs).expect("train step");
        params = out[..n].to_vec();
        m = out[n..2 * n].to_vec();
        v = out[2 * n..3 * n].to_vec();
        losses.push(out[3 * n].scalar_f32().unwrap());
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.7),
        "losses: {losses:?}"
    );
}

#[test]
fn kurtail_step_learns_rotation_and_stays_orthogonal() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let d = 64;
    let rows = rt.manifest.kurtail_rows;
    let x = Tensor::new((0..rows * d).map(|_| rng.laplace(1.0)).collect(), vec![rows, d]);
    let art = rt.load("kurtail_step_d64").expect("load");

    let mut r = Tensor::eye(d);
    let mut m = Tensor::zeros(&[d, d]);
    let mut v = 0.0f32;
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=40 {
        let out = art
            .run(&[
                r.clone().into(),
                m.clone().into(),
                Value::from(v),
                x.clone().into(),
                Value::from(0.1f32),
                Value::from(step as f32),
            ])
            .expect("kurtail step");
        r = out[0].clone().into_f32().unwrap();
        m = out[1].clone().into_f32().unwrap();
        v = out[2].scalar_f32().unwrap();
        last = out[3].scalar_f32().unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(hadamard::orthogonality_error(&r) < 1e-3);

    // host-side kurtail loss of the rotated data agrees with the artifact's
    let xr = kurtail::tensor::matmul::matmul(&x, &r);
    let host = stats::kurtail_loss(&xr);
    assert!((host - last).abs() < 0.2, "host {host} vs artifact {last}");
}

#[test]
fn manifest_abi_is_consistent() {
    let Some(rt) = runtime() else { return };
    for (name, sig) in &rt.manifest.artifacts {
        assert!(rt.dir.join(&sig.file).exists(), "{name}: missing {}", sig.file);
        assert!(!sig.inputs.is_empty() && !sig.outputs.is_empty(), "{name}");
    }
    let meta = rt.manifest.config("tiny").unwrap();
    assert_eq!(meta.d_model, meta.n_heads * meta.d_head);
    assert!(meta.param_index("embed").is_some());
    assert!(meta.param_index("head").is_some());
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let art = rt.load("kurtail_step_d64").expect("load");
    let bad = vec![Value::from(Tensor::zeros(&[3, 3]))];
    assert!(art.run(&bad).is_err());
}
