//! Steady-state zero-allocation pin for the serve decode hot path.
//!
//! The engine-owned scratch arena (`serve/scratch.rs`), the pre-packed
//! rotation/head matrices, the i8 weight panel cache, and the
//! capacity-reserving lane/KV bookkeeping together make a steady-state
//! `Engine::step()` — live lanes decoding, nothing admitted or retired
//! — perform **zero heap allocations**. This binary installs the
//! counting allocator (`util::alloc::CountingAlloc`) as the global
//! allocator and asserts exactly that.
//!
//! Deliberately a single `#[test]`: the allocation counter is global to
//! the process, so a concurrently running sibling test would pollute
//! the measurement window. The assertion runs at `threads = 1` because
//! parallel dispatch allocates by design above that — scoped thread
//! *spawns* on the static backend (stacks, join state), pool job
//! injection on the work-stealing backend — while the kernels
//! themselves never do (at `threads = 1` the steal backend runs the
//! whole row range inline and never touches the rayon pool). The
//! bitwise-equality properties in `tests/props.rs` cover thread counts
//! and backends.
//!
//! Both epilogue/backend profiles are measured: the work-stealing +
//! fused-column-major default, and the static + PR-4 serial-flip A/B
//! baseline. The scratch decay stays armed at its default — steady
//! state at a constant lane count never dips below the arena's
//! high-water mark, so decay must not fire (and must not allocate).
//! Prefix sharing and chunked prefill are pinned *on* explicitly: the
//! refcounted pool and the prefill cursor are live in the measured
//! engine, and steady-state decode must stay heap-silent with both.

mod common;
use common::serve_test_meta;

use kurtail::config::KvQuant;
use kurtail::model::Params;
use kurtail::serve::{Engine, ParBackend, ServeConfig, ServeModel, ServeQuantSpec};
use kurtail::tensor::hadamard::random_hadamard;
use kurtail::util::alloc::CountingAlloc;
use kurtail::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Warm an engine to steady state and assert a 6-step decode window
/// performs zero heap allocations, then drain it clean.
fn assert_zero_alloc_window(model: &ServeModel, cfg: &ServeConfig, label: &str) {
    let mut eng = Engine::new(model.clone(), cfg).unwrap();
    assert!(eng.arena());
    assert!(eng.panel_cache_bytes() > 0, "panel cache should be built");
    eng.submit_tokens(vec![1, 2], 12, 0.0, 7).unwrap();
    eng.submit_tokens(vec![3], 12, 0.0, 7).unwrap();

    // step 1 admits + prefills both lanes (allocates: lane setup, KV
    // block lists); two more decode steps warm every arena buffer
    assert!(eng.step().unwrap());
    assert!(eng.step().unwrap());
    assert!(eng.step().unwrap());
    assert_eq!(eng.stats.admitted, 2);
    let tokens_before = eng.stats.decode_tokens;

    let snapshot = ALLOC.allocations();
    for i in 0..6 {
        assert!(eng.step().unwrap(), "{label}: lanes must stay live through window step {i}");
    }
    let delta = ALLOC.allocations() - snapshot;
    assert_eq!(
        delta, 0,
        "{label}: steady-state decode must not touch the heap ({delta} allocation events in 6 steps)"
    );
    assert_eq!(eng.stats.decode_tokens - tokens_before, 12, "6 steps × 2 live lanes");

    // and the engine still finishes cleanly afterwards
    let done = eng.run().unwrap();
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_eq!(c.tokens.len(), c.prompt_len + 12);
    }
    assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
}

#[test]
fn steady_state_decode_is_allocation_free() {
    let meta = serve_test_meta();
    let mut rng = Rng::new(0);
    let params = Params::init(&meta, &mut rng);
    let spec = ServeQuantSpec::paper_default(
        random_hadamard(meta.d_head, &mut rng),
        random_hadamard(meta.d_head, &mut rng),
        random_hadamard(meta.d_ff, &mut rng),
    );
    let model = ServeModel::from_params(&params, Some(spec)).unwrap();
    // block_tokens = 2 makes the measurement window cross block
    // boundaries, exercising the pre-reserved SeqKv block lists
    let cfg = ServeConfig {
        max_lanes: 2,
        block_tokens: 2,
        kv_quant: KvQuant::Asym4,
        threads: Some(1),
        int_gemm: Some(true),
        arena: Some(true),
        // explicit unbounded budget (None would follow the
        // KURTAIL_PANEL_CACHE env var and break under `=0`)
        panel_cache: Some(usize::MAX),
        // telemetry ON: histogram records and gauge refreshes are part
        // of the zero-alloc contract, not exempt from it
        obs: Some(true),
        // prefix sharing + chunked prefill ON explicitly (not via the
        // env defaults): the zero-alloc window must hold with the
        // refcounted pool and the prefill cursor armed, and must not
        // quietly pass because an env var disabled them
        prefix_share: Some(true),
        prefill_chunk: Some(2),
        ..ServeConfig::default()
    };
    // the serving default: work-stealing runtime + fused epilogues
    let steal = ServeConfig {
        par_backend: Some(ParBackend::Steal),
        fused_epilogue: Some(true),
        ..cfg.clone()
    };
    assert_zero_alloc_window(&model, &steal, "steal+fused");
    // the A/B baseline: static runtime + PR-4 serial-flip epilogue
    let legacy = ServeConfig {
        par_backend: Some(ParBackend::Static),
        fused_epilogue: Some(false),
        ..cfg
    };
    assert_zero_alloc_window(&model, &legacy, "static+serial");
}
