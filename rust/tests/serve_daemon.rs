//! End-to-end daemon tests over real sockets: a raw HTTP/1.1 client
//! (no client crates either) drives `Daemon` through the full
//! request/stream/backpressure/deadline/disconnect/drain surface and
//! checks the two load-bearing invariants at every exit path:
//!
//! * completed token streams are bitwise identical to an in-process
//!   [`Engine::run`] over the same submissions — faults or not;
//! * whatever happens to a request (completion, shed, deadline, client
//!   disconnect, injected disconnect, drain), every KV block returns to
//!   the pool (`free_blocks == max_blocks` via `/stats`).
//!
//! The model is `common::serve_test_meta()` (vocab 16 < the byte
//! tokenizer's 256), so requests use `"tokens"` arrays, not `"prompt"`
//! strings.

mod common;
use common::serve_test_meta;

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use kurtail::model::Params;
use kurtail::serve::daemon::fault::FaultSpec;
use kurtail::serve::{
    Daemon, DaemonConfig, Engine, Priority, ServeConfig, ServeModel, ServeQuantSpec, TenantPolicy,
};
use kurtail::tensor::hadamard::random_hadamard;
use kurtail::util::json::Json;
use kurtail::util::Rng;

fn test_model() -> ServeModel {
    let meta = serve_test_meta();
    let mut rng = Rng::new(11);
    let params = Params::init(&meta, &mut rng);
    let quant = ServeQuantSpec::paper_default(
        random_hadamard(meta.d_head, &mut rng),
        random_hadamard(meta.d_head, &mut rng),
        random_hadamard(meta.d_ff, &mut rng),
    );
    ServeModel::from_params(&params, Some(quant)).unwrap()
}

// ------------------------------------------------- raw http client

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(&self.body).unwrap_or_else(|e| panic!("bad json body: {e:#}\n{}", self.body))
    }
}

/// Open a connection and send one request (the daemon is one-shot per
/// connection, so the response is everything until EOF).
fn send_raw(addr: SocketAddr, method: &str, path: &str, body: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("connect to daemon");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    s
}

/// Read until EOF; a severed socket (the `drop_conn` fault) yields the
/// bytes that made it onto the wire instead of a panic.
fn read_lenient(s: &mut TcpStream) -> Vec<u8> {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    loop {
        match s.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(_) => break,
        }
    }
    buf
}

/// Lenient chunked-transfer decoder: stops at the terminator, a
/// malformed size line, or a truncated chunk (severed streams).
fn unchunk(mut rest: &str) -> String {
    let mut out = String::new();
    loop {
        let nl = match rest.find("\r\n") {
            Some(p) => p,
            None => break,
        };
        let len = match usize::from_str_radix(rest[..nl].trim(), 16) {
            Ok(l) => l,
            Err(_) => break,
        };
        if len == 0 {
            break;
        }
        let start = nl + 2;
        if rest.len() < start + len {
            out.push_str(&rest[start.min(rest.len())..]);
            break;
        }
        out.push_str(&rest[start..start + len]);
        rest = &rest[start + len..];
        rest = rest.strip_prefix("\r\n").unwrap_or(rest);
    }
    out
}

fn parse_response(raw: &[u8]) -> Response {
    let text = String::from_utf8_lossy(raw).into_owned();
    let split = text.find("\r\n\r\n").expect("response head");
    let (head, rest) = (&text[..split], &text[split + 4..]);
    let mut lines = head.split("\r\n");
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let chunked = headers.iter().any(|(k, v)| k == "transfer-encoding" && v == "chunked");
    let body = if chunked { unchunk(rest) } else { rest.to_string() };
    Response { status, headers, body }
}

fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> Response {
    let mut s = send_raw(addr, method, path, body);
    let raw = read_lenient(&mut s);
    parse_response(&raw)
}

/// Read from an open stream until the first `"token"` line arrived —
/// proof the request is *live* (admitted and prefilled), not queued.
fn read_until_first_token(s: &mut TcpStream, got: &mut Vec<u8>) {
    let mut tmp = [0u8; 1024];
    while !String::from_utf8_lossy(got.as_slice()).contains("\"token\"") {
        let n = s.read(&mut tmp).expect("stream read");
        assert!(n > 0, "stream ended before the first token: {}", String::from_utf8_lossy(got));
        got.extend_from_slice(&tmp[..n]);
    }
}

/// Poll `/stats` until the engine shows ≥ 1 cancel with every KV block
/// back in the pool (the disconnect-reclaim invariant).
fn wait_for_reclaim(addr: SocketAddr, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = request(addr, "GET", "/stats", "").json();
        let canceled = stats.get("engine").unwrap().get("canceled").unwrap().as_usize().unwrap();
        let free = stats.get("free_blocks").unwrap().as_usize().unwrap();
        let max = stats.get("max_blocks").unwrap().as_usize().unwrap();
        if canceled >= 1 && free == max {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{what}: canceled={canceled} free={free}/{max} never converged"
        );
        thread::sleep(Duration::from_millis(20));
    }
}

// ------------------------------------------------------------ tests

#[test]
fn daemon_matches_in_process_engine_with_and_without_faults() {
    let model = test_model();
    let cfg = ServeConfig { max_lanes: 2, block_tokens: 4, ..ServeConfig::default() };

    // reference: the same three submissions run in-process
    let mut reference = Engine::new(model.clone(), &cfg).unwrap();
    reference.submit_tokens(vec![1, 2, 3], 4, 0.0, 7).unwrap();
    reference.submit_tokens(vec![4, 5], 3, 0.8, 9).unwrap();
    reference.submit_tokens(vec![6], 5, 0.0, 3).unwrap();
    let mut want = reference.run().unwrap();
    want.sort_by_key(|c| c.id);

    // faults shift admission timing and client visibility, never the
    // sampled tokens — completed streams stay bitwise identical
    for fault in [
        FaultSpec::none(),
        FaultSpec { pool_exhaust: 0.4, slow_step_ms: 1, seed: 42, ..FaultSpec::none() },
    ] {
        let dcfg = DaemonConfig { serve: cfg.clone(), fault: fault.clone(), ..DaemonConfig::default() };
        let daemon = Daemon::spawn(model.clone(), &dcfg).unwrap();
        let addr = daemon.addr();

        // sequential posts keep request ids aligned with the reference
        let r0 =
            request(addr, "POST", "/v1/generate", r#"{"tokens": [1, 2, 3], "max_tokens": 4, "seed": 7}"#);
        assert_eq!(r0.status, 200, "fault={fault:?}: {}", r0.body);
        let toks0: Vec<i32> = r0
            .json()
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(toks0, want[0].tokens, "completion bitwise identical (fault={fault:?})");

        let r1 = request(
            addr,
            "POST",
            "/v1/generate",
            r#"{"tokens": [4, 5], "max_tokens": 3, "temp": 0.8, "seed": 9}"#,
        );
        assert_eq!(r1.status, 200, "fault={fault:?}: {}", r1.body);
        let toks1: Vec<i32> = r1
            .json()
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(toks1, want[1].tokens, "sampled (temp>0) stream replays too (fault={fault:?})");

        // third request streams: per-token lines, then a done marker
        let r2 = request(
            addr,
            "POST",
            "/v1/generate",
            r#"{"tokens": [6], "max_tokens": 5, "seed": 3, "stream": true}"#,
        );
        assert_eq!(r2.status, 200, "fault={fault:?}");
        let streamed: Vec<i32> = r2
            .body
            .lines()
            .filter_map(|l| Json::parse(l).ok())
            .filter_map(|j| j.opt("token").and_then(|t| t.as_f64().ok()).map(|f| f as i32))
            .collect();
        assert_eq!(
            streamed,
            want[2].tokens[want[2].prompt_len..].to_vec(),
            "streamed tokens == generated suffix (fault={fault:?})"
        );
        let done = Json::parse(r2.body.lines().last().unwrap()).unwrap();
        assert!(matches!(done.opt("done"), Some(Json::Bool(true))), "stream terminates with done");
        assert_eq!(
            done.get("n_tokens").unwrap().as_usize().unwrap(),
            want[2].tokens.len() - want[2].prompt_len
        );

        let stats = request(addr, "GET", "/stats", "").json();
        assert_eq!(stats.get("engine").unwrap().get("admitted").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            stats.get("free_blocks").unwrap().as_usize().unwrap(),
            stats.get("max_blocks").unwrap().as_usize().unwrap(),
            "every KV block back after 3 completions (fault={fault:?})"
        );
        daemon.join().unwrap();
    }
}

#[test]
fn daemon_backpressure_sheds_with_retry_after() {
    // one lane, a one-deep queue and slow steps: 6 concurrent posts
    // must shed at least one request with 429 + Retry-After while at
    // least one completes
    let dcfg = DaemonConfig {
        queue_cap: 1,
        serve: ServeConfig { max_lanes: 1, block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { slow_step_ms: 10, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    let handles: Vec<_> = (0..6)
        .map(|_| {
            thread::spawn(move || request(addr, "POST", "/v1/generate", r#"{"tokens": [1, 2], "max_tokens": 4}"#))
        })
        .collect();
    let responses: Vec<Response> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = responses.iter().filter(|r| r.status == 200).count();
    let shed: Vec<&Response> = responses.iter().filter(|r| r.status == 429).collect();
    assert!(ok >= 1, "someone completes under load");
    assert!(!shed.is_empty(), "queue_cap=1 with 6 concurrent posts must shed");
    for r in &shed {
        assert_eq!(r.header("retry-after"), Some("1"), "backpressure carries Retry-After");
        assert_eq!(r.json().get("error").unwrap().as_str().unwrap(), "queue_full");
    }

    let stats = request(addr, "GET", "/stats", "").json();
    assert!(stats.get("engine").unwrap().get("shed").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(
        stats.get("free_blocks").unwrap().as_usize().unwrap(),
        stats.get("max_blocks").unwrap().as_usize().unwrap(),
        "shed requests never touch the pool"
    );
    daemon.join().unwrap();
}

#[test]
fn daemon_deadline_maps_to_504_and_returns_blocks() {
    // 30 ms steps against a 1 ms deadline: the sweep cancels the
    // request long before its 8 tokens could finish
    let dcfg = DaemonConfig {
        serve: ServeConfig { block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { slow_step_ms: 30, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    let r = request(addr, "POST", "/v1/generate", r#"{"tokens": [1, 2], "max_tokens": 8, "deadline_ms": 1}"#);
    assert_eq!(r.status, 504, "{}", r.body);
    assert_eq!(r.json().get("error").unwrap().as_str().unwrap(), "deadline");

    let stats = request(addr, "GET", "/stats", "").json();
    assert!(stats.get("engine").unwrap().get("canceled").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(
        stats.get("free_blocks").unwrap().as_usize().unwrap(),
        stats.get("max_blocks").unwrap().as_usize().unwrap(),
        "deadline cancel returned every block"
    );
    daemon.join().unwrap();
}

#[test]
fn client_disconnect_mid_stream_reclaims_blocks() {
    let dcfg = DaemonConfig {
        serve: ServeConfig { block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { slow_step_ms: 10, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();
    {
        let mut s =
            send_raw(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 12, "stream": true}"#);
        let mut got = Vec::new();
        read_until_first_token(&mut s, &mut got);
    } // drop: the client hangs up mid-stream
    wait_for_reclaim(addr, "client disconnect");
    daemon.join().unwrap();
}

#[test]
fn injected_drop_conn_severs_stream_and_reclaims() {
    // drop_conn=1.0 severs every stream after 1..=4 tokens, exercising
    // the disconnect path from the daemon side; the lenient client
    // parser sees a truncated body, never a done marker
    let dcfg = DaemonConfig {
        serve: ServeConfig { block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { drop_conn: 1.0, seed: 5, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    let r = request(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 12, "stream": true}"#);
    assert_eq!(r.status, 200, "the head went out before the sever");
    let toks = r.body.lines().filter(|l| l.contains("\"token\"")).count();
    assert!((1..=4).contains(&toks), "severed after a few tokens, got {toks}");
    assert!(!r.body.contains("\"done\""), "a severed stream must not complete: {}", r.body);

    wait_for_reclaim(addr, "injected drop_conn");
    daemon.join().unwrap();
}

#[test]
fn drain_rejects_new_work_and_finishes_live_streams() {
    let dcfg = DaemonConfig {
        serve: ServeConfig { block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { slow_step_ms: 20, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();
    let health = request(addr, "GET", "/healthz", "");
    assert_eq!(health.status, 200, "{}", health.body);
    let hj = health.json();
    assert_eq!(hj.get("status").unwrap().as_str().unwrap(), "ok");
    assert!(hj.get("version").is_ok(), "healthz carries build info: {}", health.body);

    // open a stream and wait for its first token: the lane is live, so
    // the drain must let it finish
    let mut s = send_raw(addr, "POST", "/v1/generate", r#"{"tokens": [2], "max_tokens": 10, "stream": true}"#);
    let mut got = Vec::new();
    read_until_first_token(&mut s, &mut got);

    let r = request(addr, "POST", "/admin/drain", "");
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(request(addr, "GET", "/healthz", "").status, 503, "draining flips healthz");

    let rejected = request(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 2}"#);
    assert_eq!(rejected.status, 503, "new work sheds during drain: {}", rejected.body);
    assert_eq!(rejected.header("retry-after"), Some("1"));
    assert_eq!(rejected.json().get("error").unwrap().as_str().unwrap(), "draining");

    // /stats stays reachable while draining (503 only once the engine
    // thread has already retired the last lane and exited)
    let stats = request(addr, "GET", "/stats", "");
    if stats.status == 200 {
        assert!(matches!(stats.json().get("draining"), Ok(Json::Bool(true))));
    } else {
        assert_eq!(stats.status, 503, "{}", stats.body);
    }

    // the live stream runs to completion across the drain
    got.extend_from_slice(&read_lenient(&mut s));
    let resp = parse_response(&got);
    assert!(resp.body.contains("\"done\": true"), "live stream finished: {}", resp.body);

    daemon.join().unwrap();
}

/// Parse a Prometheus text-0.0.4 body into `(series, value)` pairs,
/// panicking on duplicate series (the exposition-validity half of the
/// check) — series name here includes the label set, e.g.
/// `kurtail_tenant_requests_total{tenant="alice"}`.
fn parse_metrics(body: &str) -> Vec<(String, f64)> {
    let mut series: Vec<(String, f64)> = Vec::new();
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
        assert!(
            series.iter().all(|(n, _)| n != name),
            "duplicate series in exposition: {name}"
        );
        series.push((name.to_string(), value.parse().expect("metric value parses")));
    }
    series
}

fn metric(series: &[(String, f64)], name: &str) -> f64 {
    series
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("no series named {name}"))
        .1
}

#[test]
fn metrics_exposition_reconciles_with_stats_after_faulted_run() {
    // two completions under distinct tenants plus one deadline cancel,
    // all with slowed steps: every counter on /metrics must agree with
    // the /stats snapshot, and the latency histograms must have seen
    // exactly the admitted requests
    let dcfg = DaemonConfig {
        serve: ServeConfig { block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { slow_step_ms: 5, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    let r = request(
        addr,
        "POST",
        "/v1/generate",
        r#"{"tokens": [1, 2], "max_tokens": 3, "tenant": "alice"}"#,
    );
    assert_eq!(r.status, 200, "{}", r.body);
    // completions carry their trace span
    let body = r.json();
    let span = body.get("span").unwrap();
    assert!(span.get("queue_wait_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(span.get("new_tokens").unwrap().as_usize().unwrap(), 3);

    let r = request(addr, "POST", "/v1/generate", r#"{"tokens": [3], "max_tokens": 2, "tenant": "bob"}"#);
    assert_eq!(r.status, 200, "{}", r.body);

    let r = request(
        addr,
        "POST",
        "/v1/generate",
        r#"{"tokens": [1], "max_tokens": 8, "deadline_ms": 1, "tenant": "alice"}"#,
    );
    assert_eq!(r.status, 504, "{}", r.body);

    let m = request(addr, "GET", "/metrics", "");
    assert_eq!(m.status, 200, "{}", m.body);
    assert!(
        m.header("content-type").is_some_and(|c| c.starts_with("text/plain")),
        "prometheus content type, got {:?}",
        m.header("content-type")
    );
    let series = parse_metrics(&m.body);

    let stats = request(addr, "GET", "/stats", "").json();
    let engine = stats.get("engine").unwrap();
    let stat = |k: &str| engine.get(k).unwrap().as_f64().unwrap();

    // counters reconcile exactly with the /stats snapshot
    for (m_name, s_name) in [
        ("kurtail_requests_admitted_total", "admitted"),
        ("kurtail_requests_retired_total", "retired"),
        ("kurtail_requests_canceled_total", "canceled"),
        ("kurtail_requests_shed_total", "shed"),
        ("kurtail_prefill_tokens_total", "prefill_tokens"),
        ("kurtail_decode_tokens_total", "decode_tokens"),
    ] {
        assert_eq!(metric(&series, m_name), stat(s_name), "{m_name} != stats {s_name}");
    }
    // the two completions were certainly admitted; the deadline request
    // may be swept from the queue before ever reaching a lane, so only
    // bound it
    let admitted = stat("admitted");
    assert!((2.0..=3.0).contains(&admitted), "admitted = {admitted}");
    assert!(stat("canceled") >= 1.0, "the deadline request canceled");

    // every admitted request crossed the queue and prefilled once
    assert_eq!(metric(&series, "kurtail_queue_wait_seconds_count"), admitted);
    assert_eq!(metric(&series, "kurtail_ttft_seconds_count"), admitted);

    // tenant series: alice posted twice, bob once, and the deadline
    // cancel landed on alice
    assert_eq!(metric(&series, "kurtail_tenant_requests_total{tenant=\"alice\"}"), 2.0);
    assert_eq!(metric(&series, "kurtail_tenant_requests_total{tenant=\"bob\"}"), 1.0);
    assert_eq!(metric(&series, "kurtail_tenant_canceled_total{tenant=\"alice\"}"), 1.0);

    // the pool drained back and the gauges agree with /stats
    assert_eq!(
        metric(&series, "kurtail_kv_free_blocks"),
        stats.get("free_blocks").unwrap().as_f64().unwrap()
    );
    assert_eq!(metric(&series, "kurtail_live_lanes"), 0.0);

    // /stats mirrors the same histograms as structured quantiles
    let latency = stats.get("latency").unwrap();
    assert_eq!(latency.get("ttft").unwrap().get("count").unwrap().as_f64().unwrap(), admitted);
    assert!(latency.get("decode_phase").unwrap().get("gemm").unwrap().get("count").is_ok());

    daemon.join().unwrap();
}

#[test]
fn daemon_rejects_malformed_requests() {
    let daemon = Daemon::spawn(test_model(), &DaemonConfig::default()).unwrap();
    let addr = daemon.addr();

    let bad = request(addr, "POST", "/v1/generate", "this is not json");
    assert_eq!(bad.status, 400);
    assert_eq!(bad.json().get("error").unwrap().as_str().unwrap(), "invalid");
    assert_eq!(bad.header("retry-after"), None, "client errors are not retryable");

    // vocab is 16: out-of-range prompt tokens are a 400, not a panic
    let oov = request(addr, "POST", "/v1/generate", r#"{"tokens": [99], "max_tokens": 2}"#);
    assert_eq!(oov.status, 400, "{}", oov.body);

    // prompt + generation beyond the KV capacity is recoverable too
    let huge = request(addr, "POST", "/v1/generate", r#"{"tokens": [1, 2, 3], "max_tokens": 200}"#);
    assert_eq!(huge.status, 400, "{}", huge.body);

    assert_eq!(request(addr, "GET", "/nope", "").status, 404);

    // rejects left the engine untouched
    let stats = request(addr, "GET", "/stats", "").json();
    assert_eq!(stats.get("engine").unwrap().get("admitted").unwrap().as_usize().unwrap(), 0);
    assert_eq!(
        stats.get("free_blocks").unwrap().as_usize().unwrap(),
        stats.get("max_blocks").unwrap().as_usize().unwrap()
    );
    daemon.join().unwrap();
}

// -------------------------------------------- keep-alive client bits

/// Send one request on an already-open connection WITHOUT
/// `Connection: close`, then read exactly one `Content-Length`-framed
/// response (keep-alive means no EOF to read until).
fn send_keepalive(s: &mut TcpStream, method: &str, path: &str, body: &str) -> Response {
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    read_one_response(s)
}

fn read_one_response(s: &mut TcpStream) -> Response {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let n = s.read(&mut tmp).expect("response head read");
        assert!(n > 0, "connection closed before a full response head");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .expect("status line");
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let cl: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .expect("keep-alive responses are Content-Length framed");
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < cl {
        let n = s.read(&mut tmp).expect("response body read");
        assert!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(cl);
    Response { status, headers, body: String::from_utf8_lossy(&body).into_owned() }
}

// -------------------------------------------------------- pr-9 tests

#[test]
fn keep_alive_serves_many_requests_on_one_connection() {
    let daemon = Daemon::spawn(test_model(), &DaemonConfig::default()).unwrap();
    let addr = daemon.addr();
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    for i in 0..3 {
        let r = send_keepalive(&mut s, "GET", "/healthz", "");
        assert_eq!(r.status, 200, "request {i} on the same socket: {}", r.body);
        assert_eq!(r.header("connection"), Some("keep-alive"), "request {i}");
    }
    // a generate rides the same connection as the probes before it
    let r = send_keepalive(&mut s, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 2}"#);
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("connection"), Some("keep-alive"));

    // `Connection: close` is honoured: response says close, then EOF
    let req = "GET /healthz HTTP/1.1\r\nHost: test\r\nConnection: close\r\nContent-Length: 0\r\n\r\n";
    s.write_all(req.as_bytes()).unwrap();
    let resp = parse_response(&read_lenient(&mut s));
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(resp.header("connection"), Some("close"));

    // the shared engine saw every request from this one socket
    let stats = request(addr, "GET", "/stats", "").json();
    assert_eq!(stats.get("engine").unwrap().get("admitted").unwrap().as_usize().unwrap(), 1);
    daemon.join().unwrap();
}

#[test]
fn priority_tenant_overtakes_low_flood() {
    // one slow lane: low-class requests fill it and the queue; a
    // late-arriving high-class request must still finish before the
    // queued lows it outranks
    let mut tenants = BTreeMap::new();
    tenants.insert("vip".to_string(), TenantPolicy { priority: Priority::High, ..TenantPolicy::default() });
    tenants.insert("batch".to_string(), TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() });
    let dcfg = DaemonConfig {
        queue_cap: 8,
        tenants,
        serve: ServeConfig { max_lanes: 1, block_tokens: 4, ..ServeConfig::default() },
        fault: FaultSpec { slow_step_ms: 15, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    let lows: Vec<_> = (0..4)
        .map(|i| {
            thread::spawn(move || {
                let body =
                    format!(r#"{{"tokens": [1], "max_tokens": 6, "seed": {i}, "tenant": "batch"}}"#);
                let r = request(addr, "POST", "/v1/generate", &body);
                (r.status, Instant::now())
            })
        })
        .collect();
    thread::sleep(Duration::from_millis(60)); // let the flood queue up
    let hi = request(addr, "POST", "/v1/generate", r#"{"tokens": [2], "max_tokens": 2, "tenant": "vip"}"#);
    let hi_done = Instant::now();
    assert_eq!(hi.status, 200, "{}", hi.body);

    let low_times: Vec<(u16, Instant)> = lows.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(low_times.iter().all(|(st, _)| *st == 200), "no eviction below the queue bound");
    let overtaken = low_times.iter().filter(|(_, t)| *t > hi_done).count();
    assert!(overtaken >= 1, "the high-class request overtook at least one queued low");
    daemon.join().unwrap();
}

#[test]
fn engine_panic_resumes_in_flight_request_transparently() {
    // reference: the same submission on an engine that never crashes
    let cfg = ServeConfig { block_tokens: 4, ..ServeConfig::default() };
    let mut reference = Engine::new(test_model(), &cfg).unwrap();
    reference.submit_tokens(vec![1, 2], 3, 0.0, 7).unwrap();
    let want = reference.run().unwrap().remove(0);

    let dcfg = DaemonConfig {
        serve: cfg,
        fault: FaultSpec { engine_panic: 1.0, ..FaultSpec::none() },
        ..DaemonConfig::default()
    };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    // the first request trips the one-shot injected panic mid-flight;
    // with `resume_on_restart` (default on) the supervisor re-submits
    // it into the rebuilt engine, so the client sees a completed 200 —
    // never a 503 — and the stream matches the undisturbed run bitwise
    let r = request(addr, "POST", "/v1/generate", r#"{"tokens": [1, 2], "max_tokens": 3, "seed": 7}"#);
    assert_eq!(r.status, 200, "resume hides the restart: {}", r.body);
    let toks: Vec<i32> = r
        .json()
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(toks, want.tokens, "resumed stream is bitwise the undisturbed run");

    // exactly one restart on the books, one resumed stream, zero
    // leaked KV blocks
    let stats = request(addr, "GET", "/stats", "").json();
    assert_eq!(stats.get("engine_restarts").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        stats.get("engine").unwrap().get("resumed").unwrap().as_usize().unwrap(),
        1,
        "the in-flight request resumed instead of failing"
    );
    assert_eq!(
        stats.get("free_blocks").unwrap().as_usize().unwrap(),
        stats.get("max_blocks").unwrap().as_usize().unwrap(),
        "the crash leaked nothing"
    );
    let m = request(addr, "GET", "/metrics", "");
    assert!(m.body.contains("kurtail_engine_restarts_total 1"), "{}", m.body);
    assert!(m.body.contains("kurtail_requests_resumed_total 1"), "{}", m.body);
    daemon.join().unwrap();
}

#[test]
fn config_file_reload_applies_live() {
    // a daemon started on a config file picks up edits without restart:
    // generation bumps on /stats and the new tenant policy (a drained
    // token bucket) governs the very next admission
    let path = std::env::temp_dir().join(format!("kurtail-reload-{}.json", std::process::id()));
    std::fs::write(&path, "{\"per_tenant_cap\": 0}\n").unwrap();
    let dcfg = DaemonConfig { config_path: Some(path.clone()), ..DaemonConfig::default() };
    let daemon = Daemon::spawn(test_model(), &dcfg).unwrap();
    let addr = daemon.addr();

    let stats = request(addr, "GET", "/stats", "").json();
    assert_eq!(stats.get("config_generation").unwrap().as_usize().unwrap(), 1);
    let ok = request(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 4, "tenant": "m"}"#);
    assert_eq!(ok.status, 200, "unlimited before the reload: {}", ok.body);

    // rewrite the file: tenant "m" now has a 2-token bucket refilled at
    // 0.001 tok/s (different length than the original so the
    // (mtime, len) stamp always changes)
    std::fs::write(
        &path,
        "{\"tenants\": {\"m\": {\"rate_tokens_per_s\": 0.001, \"burst_tokens\": 2}}}\n",
    )
    .unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let gen = request(addr, "GET", "/stats", "")
            .json()
            .get("config_generation")
            .unwrap()
            .as_usize()
            .unwrap();
        if gen >= 2 {
            break;
        }
        assert!(Instant::now() < deadline, "reload never landed (generation stuck at {gen})");
        thread::sleep(Duration::from_millis(50));
    }

    // drain the fresh 2-token bucket with an admissible request (it
    // generates ≥ 1 token, so at most 1 of the 2-token charge refunds)…
    let drain = request(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 2, "tenant": "m"}"#);
    assert_eq!(drain.status, 200, "a charge within the burst admits: {}", drain.body);
    // …then 2 more tokens are ≥ 1 short: shed 429 with the
    // deficit-derived Retry-After (≥ 1 token / 0.001 tok/s clamps to 60)
    let shed = request(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 2, "tenant": "m"}"#);
    assert_eq!(shed.status, 429, "{}", shed.body);
    assert_eq!(shed.json().get("error").unwrap().as_str().unwrap(), "rate_limited");
    assert_eq!(shed.header("retry-after"), Some("60"), "Retry-After from the bucket deficit");

    // an invalid rewrite is rejected and the good config stays live
    std::fs::write(&path, "{\"nonsense\": true}\n").unwrap();
    thread::sleep(Duration::from_millis(800));
    let stats = request(addr, "GET", "/stats", "").json();
    assert_eq!(
        stats.get("config_generation").unwrap().as_usize().unwrap(),
        2,
        "bad config must not install"
    );
    let still = request(addr, "POST", "/v1/generate", r#"{"tokens": [1], "max_tokens": 4, "tenant": "m"}"#);
    assert_eq!(still.status, 429, "the pre-edit policy is still in charge: {}", still.body);

    daemon.join().unwrap();
    let _ = std::fs::remove_file(&path);
}
