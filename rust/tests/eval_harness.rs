//! Integration: the evaluation harness scores correctly against
//! hand-computable cases, and the trained model beats chance on the
//! synthetic task suites (the signal the paper's tables measure).

use std::sync::Arc;

use kurtail::config::{Method, PipelineConfig};
use kurtail::calib::Mcq;
use kurtail::eval::{mathqa_suite, mmlu_suite, score_mcqs, zero_shot_suite};
use kurtail::pipeline::Pipeline;
use kurtail::runtime::Runtime;

fn pipeline() -> Option<Pipeline> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    let rt = Arc::new(Runtime::new(dir).expect("runtime"));
    // fast=false: accuracy assertions need the fully-pretrained (300-step)
    // tiny model; the snapshot is cached, so training happens once.
    Some(Pipeline::new(rt, "tiny", 0, false, false).expect("pipeline"))
}

#[test]
fn trained_model_beats_chance_on_facts() {
    let Some(pipe) = pipeline() else { return };
    let fp = pipe.quantize(&PipelineConfig::new("tiny", Method::Fp16)).unwrap().0;
    let mmlu = mmlu_suite(&pipe.bundle.world, 25, 7);
    let mut total = 0.0;
    for set in &mmlu {
        let sc = score_mcqs(&pipe.rt, &fp, &set.questions).unwrap();
        total += sc.accuracy;
    }
    let avg = total / mmlu.len() as f32;
    // 4-way chance = 0.25; the 60-step fast-trained tiny model should
    // still have absorbed some facts
    assert!(avg > 0.28, "mmlu avg {avg} not above chance");
}

#[test]
fn scorer_prefers_verbatim_training_text() {
    let Some(pipe) = pipeline() else { return };
    let fp = pipe.quantize(&PipelineConfig::new("tiny", Method::Fp16)).unwrap().0;
    // craft an McQ where one option is a substring that certainly appears
    // in training ("the" continuation) vs junk bytes
    let q = Mcq {
        prompt: "the author of".into(),
        options: vec!["the glass river is alden.".into(), "zzqxj##@@".into()],
        correct: 0,
    };
    let sc = score_mcqs(&pipe.rt, &fp, std::slice::from_ref(&q)).unwrap();
    assert_eq!(sc.predictions[0], 0, "model should prefer corpus-like text");
}

#[test]
fn suites_have_expected_sizes() {
    let Some(pipe) = pipeline() else { return };
    let zs = zero_shot_suite(&pipe.bundle.world, 5, 1);
    assert_eq!(zs.len(), 8);
    assert!(zs.iter().all(|s| s.questions.len() == 5));
    let mq = mathqa_suite(7, 1);
    assert_eq!(mq.questions.len(), 7);
}

#[test]
fn quantization_degrades_but_does_not_destroy_accuracy() {
    let Some(pipe) = pipeline() else { return };
    let fp = pipe.quantize(&PipelineConfig::new("tiny", Method::Fp16)).unwrap().0;
    let mut cfg = PipelineConfig::new("tiny", Method::KurTail);
    cfg.seed = 7;
    cfg.calib.seed = 7;
    cfg.calib.n_samples = 32;
    cfg.calib.iters = 15;
    let kt = pipe.quantize(&cfg).unwrap().0;
    let qs = mmlu_suite(&pipe.bundle.world, 25, 7).remove(2).questions; // stem
    let a_fp = score_mcqs(&pipe.rt, &fp, &qs).unwrap().accuracy;
    let a_kt = score_mcqs(&pipe.rt, &kt, &qs).unwrap().accuracy;
    println!("stem acc fp={a_fp} kurtail={a_kt}");
    // 4-bit should stay within a broad band of fp (not collapse to ~0)
    assert!(a_kt >= a_fp - 0.35, "quantized accuracy collapsed: {a_fp} -> {a_kt}");
}
