//! Integration: the full PTQ pipeline through real artifacts on `tiny`.
//! Pins the paper's qualitative claims at the system level:
//!   * rotation fusion preserves the fp forward (computational invariance)
//!   * quantized ppl ordering: fp < rotated-4bit < unrotated-4bit
//!   * KurTail's learned rotation actually lowers the kurtosis objective
//!   * SpinQuant-lite runs and stays on the manifold

use std::sync::Arc;

use kurtail::config::{Method, PipelineConfig, WeightQuantizer};
use kurtail::eval::perplexity;
use kurtail::pipeline::{Pipeline, PreparedModel};
use kurtail::rotation::{fold_norms, fuse_r1, RotationSet};
use kurtail::runtime::Runtime;
use kurtail::tensor::hadamard::random_hadamard;
use kurtail::util::Rng;

fn pipeline() -> Option<Pipeline> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    let rt = Arc::new(Runtime::new(dir).expect("runtime"));
    // fast=true keeps pretraining at 60 steps; snapshots cache across tests
    Some(Pipeline::new(rt, "tiny", 7, true, false).expect("pipeline"))
}

fn fast_cfg(method: Method) -> PipelineConfig {
    let mut cfg = PipelineConfig::new("tiny", method);
    cfg.seed = 7;
    cfg.calib.seed = 7;
    cfg.calib.n_samples = 32;
    cfg.calib.iters = 15;
    cfg
}

#[test]
fn rotation_fusion_preserves_fp_forward() {
    let Some(pipe) = pipeline() else { return };
    let fp = PreparedModel {
        params: pipe.fp_params.clone(),
        rots: RotationSet::identity(pipe.fp_params.meta.d_head, pipe.fp_params.meta.d_ff),
        quantized: false,
        method: Method::Fp16,
    };
    let ppl_orig = perplexity(&pipe.rt, &fp, &pipe.bundle.test, 2).unwrap();

    // fold + fuse a random orthogonal rotation → fp forward must not move
    let mut params = pipe.fp_params.clone();
    fold_norms(&mut params);
    let mut rng = Rng::new(3);
    let r1 = random_hadamard(params.meta.d_model, &mut rng);
    fuse_r1(&mut params, &r1);
    let rotated = PreparedModel {
        params,
        rots: RotationSet::identity(pipe.fp_params.meta.d_head, pipe.fp_params.meta.d_ff),
        quantized: false,
        method: Method::Fp16,
    };
    let ppl_rot = perplexity(&pipe.rt, &rotated, &pipe.bundle.test, 2).unwrap();
    assert!(
        (ppl_rot - ppl_orig).abs() / ppl_orig < 0.02,
        "computational invariance violated: {ppl_orig} vs {ppl_rot}"
    );
}

#[test]
fn ppl_ordering_matches_paper_shape() {
    let Some(pipe) = pipeline() else { return };
    let fp = pipe.quantize(&fast_cfg(Method::Fp16)).unwrap().0;
    let gptq = pipe.quantize(&fast_cfg(Method::GptqOnly)).unwrap().0;
    let kurtail = pipe.quantize(&fast_cfg(Method::KurTail)).unwrap().0;

    let p_fp = perplexity(&pipe.rt, &fp, &pipe.bundle.test, 4).unwrap();
    let p_g = perplexity(&pipe.rt, &gptq, &pipe.bundle.test, 4).unwrap();
    let p_k = perplexity(&pipe.rt, &kurtail, &pipe.bundle.test, 4).unwrap();
    println!("ppl fp={p_fp:.3} kurtail={p_k:.3} gptq-only={p_g:.3}");
    assert!(p_fp < p_k, "fp must beat quantized");
    assert!(p_k < p_g, "rotations must beat no-rotations at W4A4KV4");
}

#[test]
fn kurtail_learning_reduces_objective() {
    let Some(pipe) = pipeline() else { return };
    let mut params = pipe.fp_params.clone();
    fold_norms(&mut params);
    let batches = pipe.bundle.calib_batches(kurtail::calib::CorpusKind::Wiki, 32, 4, 7);
    let mut calib = kurtail::config::CalibConfig::default();
    calib.iters = 25;
    calib.seed = 7;
    let rep = kurtail::kurtail::learn_rotations(&pipe.rt, &params, &batches, &calib).unwrap();
    let first = rep.r1_losses.first().unwrap();
    let last = rep.r1_losses.last().unwrap();
    assert!(last <= first, "kurtosis loss should not increase: {first} -> {last}");
    assert!(
        kurtail::tensor::hadamard::orthogonality_error(&rep.r1) < 1e-3,
        "R1 must stay orthogonal"
    );
    assert_eq!(rep.r2.len(), params.meta.n_layers);
}

#[test]
fn spinquant_runs_and_stays_orthogonal() {
    let Some(pipe) = pipeline() else { return };
    let mut params = pipe.fp_params.clone();
    fold_norms(&mut params);
    let batches = pipe.bundle.calib_batches(kurtail::calib::CorpusKind::Wiki, 8, 4, 7);
    let rep =
        kurtail::baselines::spinquant_learn(&pipe.rt, &params, &batches, 5, 1e-3, 7).unwrap();
    assert_eq!(rep.losses.len(), 5);
    assert!(rep.losses.iter().all(|l| l.is_finite()));
    assert!(kurtail::tensor::hadamard::orthogonality_error(&rep.r1) < 1e-3);
}

#[test]
fn rtn_weight_quantizer_also_works() {
    let Some(pipe) = pipeline() else { return };
    let mut cfg = fast_cfg(Method::QuaRot);
    cfg.weight_quantizer = WeightQuantizer::Rtn;
    let pm = pipe.quantize(&cfg).unwrap().0;
    let ppl = perplexity(&pipe.rt, &pm, &pipe.bundle.test, 2).unwrap();
    assert!(ppl.is_finite() && ppl > 1.0);
}

#[test]
fn moe_pipeline_end_to_end() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = Arc::new(Runtime::new(dir).expect("runtime"));
    let pipe = Pipeline::new(rt, "moe", 7, true, false).expect("pipeline");
    let mut cfg = PipelineConfig::new("moe", Method::KurTail);
    cfg.seed = 7;
    cfg.calib.seed = 7;
    cfg.calib.n_samples = 16;
    cfg.calib.iters = 8;
    cfg.weight_quantizer = WeightQuantizer::Rtn;
    let (pm, _) = pipe.quantize(&cfg).unwrap();
    let ppl = perplexity(&pipe.rt, &pm, &pipe.bundle.test, 2).unwrap();
    assert!(ppl.is_finite(), "moe quantized ppl finite");
}
