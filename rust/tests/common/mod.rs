//! Shared fixtures for the integration-test binaries (`tests/common/`
//! is the cargo convention for a non-test helper module).

use kurtail::runtime::{ConfigMeta, ParamSpec};

/// Tiny llama meta for serve-engine tests (no artifacts involved):
/// 2 layers, d=8, 2 heads, ff=16, vocab=16, seq_len 16. One definition
/// shared by `tests/props.rs` (bitwise-transparency properties) and
/// `tests/serve_scratch.rs` (zero-allocation pin) so both measure the
/// same model shape.
pub fn serve_test_meta() -> ConfigMeta {
    let (l, d, ff, v, h) = (2usize, 8usize, 16usize, 16usize, 2usize);
    let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.into(), shape };
    ConfigMeta {
        name: "servetest".into(),
        vocab: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_head: d / h,
        d_ff: ff,
        seq_len: 16,
        arch: "llama".into(),
        n_experts: 1,
        top_k: 1,
        train_batch: 1,
        eval_batch: 1,
        cap_batch: 1,
        decode_batch: 1,
        spin_batch: 1,
        param_specs: vec![
            spec("embed", vec![v, d]),
            spec("ln1", vec![l, d]),
            spec("wq", vec![l, d, d]),
            spec("wk", vec![l, d, d]),
            spec("wv", vec![l, d, d]),
            spec("wo", vec![l, d, d]),
            spec("ln2", vec![l, d]),
            spec("wg", vec![l, d, ff]),
            spec("wu", vec![l, d, ff]),
            spec("wd", vec![l, ff, d]),
            spec("lnf", vec![d]),
            spec("head", vec![v, d]),
        ],
    }
}
