//! Serve-engine ↔ artifact-decode parity (needs `make artifacts`;
//! self-skips like the other PJRT integration tests). The host-only
//! engine invariants (thread/lane bitwise determinism, int4 and KV
//! round-trips) live in `tests/props.rs` and the serve unit tests —
//! these tests pin the cross-implementation claims.

use std::sync::Arc;

use kurtail::config::{Method, PipelineConfig, WeightQuantizer};
use kurtail::model::generate::Generator;
use kurtail::pipeline::Pipeline;
use kurtail::runtime::Runtime;

fn pipeline() -> Option<Pipeline> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    let rt = Arc::new(Runtime::new(dir).expect("runtime"));
    Some(Pipeline::new(rt, "tiny", 7, true, false).expect("pipeline"))
}

#[test]
fn native_serve_matches_artifact_greedy_fp() {
    let Some(pipe) = pipeline() else { return };
    let gen = Generator::new(&pipe.rt, pipe.fp_params.clone(), false, None).unwrap();
    let native = gen.generate("the world is", 24, 0.0, 7).unwrap();
    let art = gen.generate_artifact("the world is", 24, 0.0, 7).unwrap();
    assert_eq!(
        native, art,
        "fp serve path must reproduce the artifact greedy stream at temp=0"
    );
}

#[test]
fn quant_serve_runs_with_kv_savings() {
    let Some(pipe) = pipeline() else { return };
    let mut cfg = PipelineConfig::new("tiny", Method::KurTail);
    cfg.seed = 7;
    cfg.calib.seed = 7;
    cfg.calib.n_samples = 32;
    cfg.calib.iters = 10;
    // RTN grids repack into Int4Weight exactly; GPTQ would re-grid
    cfg.weight_quantizer = WeightQuantizer::Rtn;
    let (pm, _) = pipe.quantize(&cfg).unwrap();
    let rots = (pm.rots.r3.clone(), pm.rots.r4.clone(), pm.rots.r5.clone());
    let gen = Generator::new(&pipe.rt, pm.params.clone(), true, Some(rots)).unwrap();

    // the native quant stream exists, has the right shape, and both
    // paths decode from the same prompt (the documented 4-bit KV +
    // f32-op-order deltas may let greedy tails diverge, so token-exact
    // agreement is only asserted for the fp path above)
    let native = gen.generate("the author of", 12, 0.0, 7).unwrap();
    let art = gen.generate_artifact("the author of", 12, 0.0, 7).unwrap();
    assert_eq!(native.len(), art.len());
    for (n, a) in native.iter().zip(&art) {
        assert!(n.starts_with("the author of"), "native stream lost its prompt: {n:?}");
        assert!(a.starts_with("the author of"), "artifact stream lost its prompt: {a:?}");
    }

    // and the serve pipeline entry reports the ≥6x-at-dh64 style ratio
    // scaled to this config's head dim
    let eng = pipe
        .serve_engine(&pm, &kurtail::serve::ServeConfig::default())
        .unwrap();
    assert!(eng.kv_bytes_per_token() < eng.dense_kv_bytes_per_token());
}
