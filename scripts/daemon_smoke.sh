#!/usr/bin/env bash
# Daemon smoke test: start `kurtail daemon --synthetic`, stream one
# request over real HTTP, check /stats invariants (at least one request
# admitted, zero leaked KV blocks), scrape /metrics mid-run and check
# the Prometheus counters reconcile with the driven load, then SIGTERM
# it and assert a clean drained exit (exit code 0, "drained clean" on
# stdout).
#
# Usage: scripts/daemon_smoke.sh [path/to/kurtail]
#        KURTAIL_SMOKE_PORT overrides the port (default 8473).
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
bin="${1:-$repo_root/rust/target/release/kurtail}"
port="${KURTAIL_SMOKE_PORT:-8473}"
base="http://127.0.0.1:$port"
log="$(mktemp)"

if [[ ! -x "$bin" ]]; then
  echo "daemon_smoke: no binary at $bin — build with 'cargo build --release' first" >&2
  exit 2
fi

"$bin" daemon --synthetic --addr "127.0.0.1:$port" >"$log" 2>&1 &
pid=$!
cleanup() {
  kill -9 "$pid" 2>/dev/null || true
  cat "$log" >&2 || true
  rm -f "$log"
}
trap cleanup EXIT

# wait for the daemon to come up
for _ in $(seq 1 100); do
  if curl -sf "$base/healthz" >/dev/null 2>&1; then
    break
  fi
  if ! kill -0 "$pid" 2>/dev/null; then
    echo "daemon_smoke: daemon exited during startup" >&2
    exit 1
  fi
  sleep 0.1
done
curl -sf "$base/healthz" | grep -q ok
curl -sf "$base/healthz" | grep -q '"version"'
echo "daemon_smoke: daemon is up on $base"

# stream one request: expect per-token ndjson lines and a done marker
stream="$(curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "hello kurtail", "max_tokens": 8, "stream": true}')"
echo "$stream" | grep -q '"token"'
echo "$stream" | grep -q '"done": true'
echo "daemon_smoke: streamed a completion"

# one non-streaming request too (plain request/response path)
curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "kurtosis", "max_tokens": 4}' | grep -q '"tokens"'

# /stats: admitted >= 1 and every KV block back in the pool
curl -sf "$base/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["engine"]["admitted"] >= 2, s
assert s["free_blocks"] == s["max_blocks"], "leaked KV blocks: %s" % s
assert "tok_s" in s and "shed" in s["engine"], s
print("daemon_smoke: stats ok —", s["engine"]["admitted"], "admitted,",
      s["free_blocks"], "/", s["max_blocks"], "blocks free")
'

# /metrics: valid exposition (no duplicate series), counters match the
# two driven requests, TTFT histogram saw each of them
curl -sf "$base/metrics" | python3 -c '
import sys
lines = [l.rstrip("\n") for l in sys.stdin if l.strip()]
series = {}
for l in lines:
    if l.startswith("#"):
        continue
    name, _, value = l.rpartition(" ")
    assert name not in series, "duplicate series: %s" % name
    series[name] = float(value)
admitted = series["kurtail_requests_admitted_total"]
assert admitted == 2, "admitted %s != 2 driven requests" % admitted
assert series["kurtail_requests_retired_total"] == admitted, series
assert series["kurtail_ttft_seconds_count"] == admitted, series
assert series["kurtail_queue_wait_seconds_count"] == admitted, series
tenant = sum(v for k, v in series.items()
             if k.startswith("kurtail_tenant_requests_total"))
assert tenant == admitted, "tenant totals %s != admitted %s" % (tenant, admitted)
assert "kurtail_kv_free_blocks" in series and "kurtail_live_lanes" in series, series
print("daemon_smoke: metrics ok —", len(series), "series,",
      int(admitted), "admitted")
'

# SIGTERM → graceful drain → clean exit
kill -TERM "$pid"
status=0
wait "$pid" || status=$?
if [[ "$status" -ne 0 ]]; then
  echo "daemon_smoke: daemon exited with status $status after SIGTERM" >&2
  exit 1
fi
grep -q "drained clean" "$log"
trap - EXIT
rm -f "$log"
echo "daemon_smoke: SIGTERM drained clean"
