#!/usr/bin/env bash
# Daemon smoke test: start `kurtail daemon --synthetic` with a runtime
# config file, stream one request over real HTTP, check /stats
# invariants (at least one request admitted, zero leaked KV blocks),
# scrape /metrics mid-run and check the Prometheus counters reconcile
# with the driven load, SIGHUP-reload the config live (generation bumps,
# a mid-flight stream survives, the new tenant policy sheds 429, an
# invalid rewrite is rejected without killing the old config), run a
# second instance under `KURTAIL_FAULT=engine_panic=1` and check the
# supervisor path (first request 503 retryable, retry 200, exactly one
# restart, zero leaked blocks), then SIGTERM everything and assert a
# clean drained exit (exit code 0, "drained clean" on stdout).
#
# Usage: scripts/daemon_smoke.sh [path/to/kurtail]
#        KURTAIL_SMOKE_PORT overrides the port (default 8473; the
#        engine-panic stage uses port+1).
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
bin="${1:-$repo_root/rust/target/release/kurtail}"
port="${KURTAIL_SMOKE_PORT:-8473}"
base="http://127.0.0.1:$port"
log="$(mktemp)"
log2="$(mktemp)"
cfg="$(mktemp)"
streamf="$(mktemp)"

if [[ ! -x "$bin" ]]; then
  echo "daemon_smoke: no binary at $bin — build with 'cargo build --release' first" >&2
  exit 2
fi

# benign startup config: the reload stage rewrites it and SIGHUPs
printf '{"per_tenant_cap": 0}\n' >"$cfg"

"$bin" daemon --synthetic --addr "127.0.0.1:$port" --config "$cfg" >"$log" 2>&1 &
pid=$!
pid2=""
cleanup() {
  kill -9 "$pid" 2>/dev/null || true
  [[ -n "$pid2" ]] && kill -9 "$pid2" 2>/dev/null || true
  cat "$log" >&2 || true
  rm -f "$log" "$log2" "$cfg" "$streamf"
}
trap cleanup EXIT

# wait for the daemon to come up
for _ in $(seq 1 100); do
  if curl -sf "$base/healthz" >/dev/null 2>&1; then
    break
  fi
  if ! kill -0 "$pid" 2>/dev/null; then
    echo "daemon_smoke: daemon exited during startup" >&2
    exit 1
  fi
  sleep 0.1
done
curl -sf "$base/healthz" | grep -q ok
curl -sf "$base/healthz" | grep -q '"version"'
echo "daemon_smoke: daemon is up on $base"

# stream one request: expect per-token ndjson lines and a done marker
stream="$(curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "hello kurtail", "max_tokens": 8, "stream": true}')"
echo "$stream" | grep -q '"token"'
echo "$stream" | grep -q '"done": true'
echo "daemon_smoke: streamed a completion"

# one non-streaming request too (plain request/response path)
curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "kurtosis", "max_tokens": 4}' | grep -q '"tokens"'

# /stats: admitted >= 1 and every KV block back in the pool
curl -sf "$base/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["engine"]["admitted"] >= 2, s
assert s["free_blocks"] == s["max_blocks"], "leaked KV blocks: %s" % s
assert "tok_s" in s and "shed" in s["engine"], s
print("daemon_smoke: stats ok —", s["engine"]["admitted"], "admitted,",
      s["free_blocks"], "/", s["max_blocks"], "blocks free")
'

# /metrics: valid exposition (no duplicate series), counters match the
# two driven requests, TTFT histogram saw each of them
curl -sf "$base/metrics" | python3 -c '
import sys
lines = [l.rstrip("\n") for l in sys.stdin if l.strip()]
series = {}
for l in lines:
    if l.startswith("#"):
        continue
    name, _, value = l.rpartition(" ")
    assert name not in series, "duplicate series: %s" % name
    series[name] = float(value)
admitted = series["kurtail_requests_admitted_total"]
assert admitted == 2, "admitted %s != 2 driven requests" % admitted
assert series["kurtail_requests_retired_total"] == admitted, series
assert series["kurtail_ttft_seconds_count"] == admitted, series
assert series["kurtail_queue_wait_seconds_count"] == admitted, series
tenant = sum(v for k, v in series.items()
             if k.startswith("kurtail_tenant_requests_total"))
assert tenant == admitted, "tenant totals %s != admitted %s" % (tenant, admitted)
assert "kurtail_kv_free_blocks" in series and "kurtail_live_lanes" in series, series
print("daemon_smoke: metrics ok —", len(series), "series,",
      int(admitted), "admitted")
'

# --- live config reload (SIGHUP) --------------------------------------
# boot generation is 1; a stream started before the reload must survive
curl -sf "$base/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["config_generation"] == 1, s
'
curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "reload survivor", "max_tokens": 48, "stream": true}' >"$streamf" &
stream_pid=$!
sleep 0.2
# rewrite: rate-limit tenant "metered" to a 2-token burst, then SIGHUP
printf '{"tenants": {"metered": {"rate_tokens_per_s": 0.001, "burst_tokens": 2}}}\n' >"$cfg"
kill -HUP "$pid"
gen=1
for _ in $(seq 1 100); do
  gen="$(curl -sf "$base/stats" | python3 -c 'import json, sys; print(json.load(sys.stdin)["config_generation"])')"
  [[ "$gen" -ge 2 ]] && break
  sleep 0.1
done
if [[ "$gen" -lt 2 ]]; then
  echo "daemon_smoke: SIGHUP reload never landed (generation $gen)" >&2
  exit 1
fi
wait "$stream_pid"
grep -q '"done": true' "$streamf"
echo "daemon_smoke: SIGHUP reload landed (generation $gen), in-flight stream survived"
# the new policy is live: "metered" asking for 8 tokens against a
# 2-token burst sheds 429 with a Retry-After from the bucket deficit
code="$(curl -s -o /dev/null -w '%{http_code}' -X POST "$base/v1/generate" \
  -d '{"prompt": "x", "max_tokens": 8, "tenant": "metered"}')"
if [[ "$code" != 429 ]]; then
  echo "daemon_smoke: rate-limited tenant got $code, expected 429" >&2
  exit 1
fi
# an invalid rewrite is rejected: generation holds, old config survives
printf '{"per_tenant_cap": "not a number"}\n' >"$cfg"
kill -HUP "$pid"
sleep 0.5
curl -sf "$base/stats" | python3 -c "
import json, sys
s = json.load(sys.stdin)
assert s['config_generation'] == $gen, 'invalid config must not bump the generation: %s' % s
"
code="$(curl -s -o /dev/null -w '%{http_code}' -X POST "$base/v1/generate" \
  -d '{"prompt": "x", "max_tokens": 8, "tenant": "metered"}')"
if [[ "$code" != 429 ]]; then
  echo "daemon_smoke: old policy should survive an invalid reload, got $code" >&2
  exit 1
fi
echo "daemon_smoke: invalid config rejected, previous config stayed live"

# --- engine-panic supervision ------------------------------------------
# a second instance armed with a one-shot engine panic: the first
# request rides the panicking step and gets a retryable 503; the retry
# lands on the rebuilt engine; exactly one restart, zero leaked blocks
port2=$((port + 1))
base2="http://127.0.0.1:$port2"
KURTAIL_FAULT="engine_panic=1" "$bin" daemon --synthetic --addr "127.0.0.1:$port2" >"$log2" 2>&1 &
pid2=$!
for _ in $(seq 1 100); do
  if curl -sf "$base2/healthz" >/dev/null 2>&1; then
    break
  fi
  if ! kill -0 "$pid2" 2>/dev/null; then
    echo "daemon_smoke: fault daemon exited during startup" >&2
    cat "$log2" >&2
    exit 1
  fi
  sleep 0.1
done
hdrs="$(mktemp)"
body="$(curl -s -D "$hdrs" -X POST "$base2/v1/generate" \
  -d '{"prompt": "panic ride", "max_tokens": 4}')"
grep -q "503" "$hdrs"
echo "$body" | grep -q '"engine_restarting"'
grep -qi "^retry-after:" "$hdrs"
rm -f "$hdrs"
curl -sf -X POST "$base2/v1/generate" \
  -d '{"prompt": "panic ride", "max_tokens": 4}' | grep -q '"tokens"'
curl -sf "$base2/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["engine_restarts"] == 1, "expected exactly one restart: %s" % s
assert s["free_blocks"] == s["max_blocks"], "leaked KV blocks across restart: %s" % s
'
curl -sf "$base2/metrics" | grep -q "^kurtail_engine_restarts_total 1$"
kill -TERM "$pid2"
status=0
wait "$pid2" || status=$?
if [[ "$status" -ne 0 ]]; then
  echo "daemon_smoke: fault daemon exited with status $status after SIGTERM" >&2
  cat "$log2" >&2
  exit 1
fi
pid2=""
echo "daemon_smoke: engine panic supervised — 503, retry ok, 1 restart, no leak"

# SIGTERM → graceful drain → clean exit
kill -TERM "$pid"
status=0
wait "$pid" || status=$?
if [[ "$status" -ne 0 ]]; then
  echo "daemon_smoke: daemon exited with status $status after SIGTERM" >&2
  exit 1
fi
grep -q "drained clean" "$log"
trap - EXIT
rm -f "$log"
echo "daemon_smoke: SIGTERM drained clean"
