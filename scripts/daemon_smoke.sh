#!/usr/bin/env bash
# Daemon smoke test: start `kurtail daemon --synthetic` with a runtime
# config file, stream one request over real HTTP, check /stats
# invariants (at least one request admitted, zero leaked KV blocks),
# scrape /metrics mid-run and check the Prometheus counters reconcile
# with the driven load, SIGHUP-reload the config live (generation bumps,
# a mid-flight stream survives, the new tenant policy sheds 429, an
# invalid rewrite is rejected without killing the old config), run a
# second instance under `KURTAIL_FAULT=engine_panic=1` and check the
# transparent-resume supervisor path (the request riding the panic
# completes with a 200 and the same bytes as a rerun — zero 503s —
# exactly one restart, zero leaked blocks), run a third instance under
# `KURTAIL_FAULT=kv_pressure=...` with high/low tenant classes and
# check KV-pressure preemption (a live low-priority stream pauses for a
# high-priority arrival, then resumes and completes with the same bytes
# as an uncontended run), then SIGTERM everything and assert a clean
# drained exit (exit code 0, "drained clean" on stdout).
#
# Usage: scripts/daemon_smoke.sh [path/to/kurtail]
#        KURTAIL_SMOKE_PORT overrides the port (default 8473; the
#        engine-panic stage uses port+1, the kv-pressure stage port+2).
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
bin="${1:-$repo_root/rust/target/release/kurtail}"
port="${KURTAIL_SMOKE_PORT:-8473}"
base="http://127.0.0.1:$port"
log="$(mktemp)"
log2="$(mktemp)"
log3="$(mktemp)"
cfg="$(mktemp)"
cfg3="$(mktemp)"
streamf="$(mktemp)"
lowref="$(mktemp)"
lowstream="$(mktemp)"

if [[ ! -x "$bin" ]]; then
  echo "daemon_smoke: no binary at $bin — build with 'cargo build --release' first" >&2
  exit 2
fi

# benign startup config: the reload stage rewrites it and SIGHUPs
printf '{"per_tenant_cap": 0}\n' >"$cfg"

"$bin" daemon --synthetic --addr "127.0.0.1:$port" --config "$cfg" >"$log" 2>&1 &
pid=$!
pid2=""
pid3=""
cleanup() {
  kill -9 "$pid" 2>/dev/null || true
  [[ -n "$pid2" ]] && kill -9 "$pid2" 2>/dev/null || true
  [[ -n "$pid3" ]] && kill -9 "$pid3" 2>/dev/null || true
  cat "$log" >&2 || true
  rm -f "$log" "$log2" "$log3" "$cfg" "$cfg3" "$streamf" "$lowref" "$lowstream"
}
trap cleanup EXIT

# wait for the daemon to come up
for _ in $(seq 1 100); do
  if curl -sf "$base/healthz" >/dev/null 2>&1; then
    break
  fi
  if ! kill -0 "$pid" 2>/dev/null; then
    echo "daemon_smoke: daemon exited during startup" >&2
    exit 1
  fi
  sleep 0.1
done
curl -sf "$base/healthz" | grep -q ok
curl -sf "$base/healthz" | grep -q '"version"'
echo "daemon_smoke: daemon is up on $base"

# stream one request: expect per-token ndjson lines and a done marker
stream="$(curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "hello kurtail", "max_tokens": 8, "stream": true}')"
echo "$stream" | grep -q '"token"'
echo "$stream" | grep -q '"done": true'
echo "daemon_smoke: streamed a completion"

# one non-streaming request too (plain request/response path)
curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "kurtosis", "max_tokens": 4}' | grep -q '"tokens"'

# /stats: admitted >= 1 and every KV block back in the pool
curl -sf "$base/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["engine"]["admitted"] >= 2, s
assert s["free_blocks"] == s["max_blocks"], "leaked KV blocks: %s" % s
assert "tok_s" in s and "shed" in s["engine"], s
print("daemon_smoke: stats ok —", s["engine"]["admitted"], "admitted,",
      s["free_blocks"], "/", s["max_blocks"], "blocks free")
'

# /metrics: valid exposition (no duplicate series), counters match the
# two driven requests, TTFT histogram saw each of them
curl -sf "$base/metrics" | python3 -c '
import sys
lines = [l.rstrip("\n") for l in sys.stdin if l.strip()]
series = {}
for l in lines:
    if l.startswith("#"):
        continue
    name, _, value = l.rpartition(" ")
    assert name not in series, "duplicate series: %s" % name
    series[name] = float(value)
admitted = series["kurtail_requests_admitted_total"]
assert admitted == 2, "admitted %s != 2 driven requests" % admitted
assert series["kurtail_requests_retired_total"] == admitted, series
assert series["kurtail_ttft_seconds_count"] == admitted, series
assert series["kurtail_queue_wait_seconds_count"] == admitted, series
tenant = sum(v for k, v in series.items()
             if k.startswith("kurtail_tenant_requests_total"))
assert tenant == admitted, "tenant totals %s != admitted %s" % (tenant, admitted)
assert "kurtail_kv_free_blocks" in series and "kurtail_live_lanes" in series, series
print("daemon_smoke: metrics ok —", len(series), "series,",
      int(admitted), "admitted")
'

# --- live config reload (SIGHUP) --------------------------------------
# boot generation is 1; a stream started before the reload must survive
curl -sf "$base/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["config_generation"] == 1, s
'
curl -sf -X POST "$base/v1/generate" \
  -d '{"prompt": "reload survivor", "max_tokens": 48, "stream": true}' >"$streamf" &
stream_pid=$!
sleep 0.2
# rewrite: rate-limit tenant "metered" to a 2-token burst, then SIGHUP
printf '{"tenants": {"metered": {"rate_tokens_per_s": 0.001, "burst_tokens": 2}}}\n' >"$cfg"
kill -HUP "$pid"
gen=1
for _ in $(seq 1 100); do
  gen="$(curl -sf "$base/stats" | python3 -c 'import json, sys; print(json.load(sys.stdin)["config_generation"])')"
  [[ "$gen" -ge 2 ]] && break
  sleep 0.1
done
if [[ "$gen" -lt 2 ]]; then
  echo "daemon_smoke: SIGHUP reload never landed (generation $gen)" >&2
  exit 1
fi
wait "$stream_pid"
grep -q '"done": true' "$streamf"
echo "daemon_smoke: SIGHUP reload landed (generation $gen), in-flight stream survived"
# the new policy is live: "metered" asking for 8 tokens against a
# 2-token burst sheds 429 with a Retry-After from the bucket deficit
code="$(curl -s -o /dev/null -w '%{http_code}' -X POST "$base/v1/generate" \
  -d '{"prompt": "x", "max_tokens": 8, "tenant": "metered"}')"
if [[ "$code" != 429 ]]; then
  echo "daemon_smoke: rate-limited tenant got $code, expected 429" >&2
  exit 1
fi
# an invalid rewrite is rejected: generation holds, old config survives
printf '{"per_tenant_cap": "not a number"}\n' >"$cfg"
kill -HUP "$pid"
sleep 0.5
curl -sf "$base/stats" | python3 -c "
import json, sys
s = json.load(sys.stdin)
assert s['config_generation'] == $gen, 'invalid config must not bump the generation: %s' % s
"
code="$(curl -s -o /dev/null -w '%{http_code}' -X POST "$base/v1/generate" \
  -d '{"prompt": "x", "max_tokens": 8, "tenant": "metered"}')"
if [[ "$code" != 429 ]]; then
  echo "daemon_smoke: old policy should survive an invalid reload, got $code" >&2
  exit 1
fi
echo "daemon_smoke: invalid config rejected, previous config stayed live"

# --- engine-panic supervision with transparent resume ------------------
# a second instance armed with a one-shot engine panic: the request
# riding the panicking step must NOT see a 503 — the supervisor
# rebuilds the engine, replays the host-side snapshot, and the stream
# completes with a 200 and the same bytes a rerun on the rebuilt engine
# produces; exactly one restart, zero leaked blocks
port2=$((port + 1))
base2="http://127.0.0.1:$port2"
KURTAIL_FAULT="engine_panic=1" "$bin" daemon --synthetic --addr "127.0.0.1:$port2" >"$log2" 2>&1 &
pid2=$!
for _ in $(seq 1 100); do
  if curl -sf "$base2/healthz" >/dev/null 2>&1; then
    break
  fi
  if ! kill -0 "$pid2" 2>/dev/null; then
    echo "daemon_smoke: fault daemon exited during startup" >&2
    cat "$log2" >&2
    exit 1
  fi
  sleep 0.1
done
hdrs="$(mktemp)"
body="$(curl -s -D "$hdrs" -X POST "$base2/v1/generate" \
  -d '{"prompt": "panic ride", "max_tokens": 4}')"
grep -q " 200 " "$hdrs"
rm -f "$hdrs"
echo "$body" | grep -q '"tokens"'
# the same request on the rebuilt (now panic-free) engine is the
# undisturbed reference: greedy decode must be bitwise identical
retry="$(curl -sf -X POST "$base2/v1/generate" \
  -d '{"prompt": "panic ride", "max_tokens": 4}')"
python3 - "$body" "$retry" <<'PY'
import json, sys
rode, clean = json.loads(sys.argv[1]), json.loads(sys.argv[2])
assert rode["tokens"] == clean["tokens"], \
    "resume across the restart changed the bytes: %s vs %s" % (rode, clean)
PY
curl -sf "$base2/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["engine_restarts"] == 1, "expected exactly one restart: %s" % s
assert s["engine"]["resumed"] == 1, "expected one resumed stream: %s" % s
assert s["free_blocks"] == s["max_blocks"], "leaked KV blocks across restart: %s" % s
'
curl -sf "$base2/metrics" | grep -q "^kurtail_engine_restarts_total 1$"
curl -sf "$base2/metrics" | grep -q "^kurtail_requests_resumed_total 1$"
kill -TERM "$pid2"
status=0
wait "$pid2" || status=$?
if [[ "$status" -ne 0 ]]; then
  echo "daemon_smoke: fault daemon exited with status $status after SIGTERM" >&2
  cat "$log2" >&2
  exit 1
fi
pid2=""
echo "daemon_smoke: engine panic supervised — zero 503s, bitwise resume, 1 restart, no leak"

# --- KV-pressure preemption with transparent resume ---------------------
# a third instance: the kv_pressure fault withholds 46 of the synthetic
# engine's 64 blocks (effective pool 18), slow_step stretches each step
# so the stage has time to interleave, and the config file defines a
# high-class "vip" tenant and a low-class "batch" tenant. A 17-token
# batch prompt + 40 new tokens needs 16 blocks — it fits alone (and
# sits above the 0.85 watermark), but a vip arrival (4 blocks > the 2
# uncommitted) must preempt it: the live low stream pauses, vip admits
# and completes, then the low stream resumes and completes with exactly
# the bytes an uncontended run produces.
port3=$((port + 2))
base3="http://127.0.0.1:$port3"
printf '{"tenants": {"vip": {"priority": "high"}, "batch": {"priority": "low"}}}\n' >"$cfg3"
KURTAIL_FAULT="kv_pressure=46,slow_step=20" "$bin" daemon --synthetic \
  --addr "127.0.0.1:$port3" --config "$cfg3" >"$log3" 2>&1 &
pid3=$!
for _ in $(seq 1 100); do
  if curl -sf "$base3/healthz" >/dev/null 2>&1; then
    break
  fi
  if ! kill -0 "$pid3" 2>/dev/null; then
    echo "daemon_smoke: pressure daemon exited during startup" >&2
    cat "$log3" >&2
    exit 1
  fi
  sleep 0.1
done
# uncontended reference run (same engine, no vip competition)
curl -sf -X POST "$base3/v1/generate" \
  -d '{"prompt": "hold the low lane", "max_tokens": 40, "tenant": "batch"}' >"$lowref"
grep -q '"tokens"' "$lowref"
# live run: start the low stream, let it emit a few tokens, then land a
# high-priority admission that cannot fit without preempting it
curl -sf -X POST "$base3/v1/generate" \
  -d '{"prompt": "hold the low lane", "max_tokens": 40, "tenant": "batch", "stream": true}' >"$lowstream" &
low_pid=$!
sleep 0.4
vip="$(curl -sf -X POST "$base3/v1/generate" \
  -d '{"prompt": "vip", "max_tokens": 10, "tenant": "vip"}')"
echo "$vip" | grep -q '"tokens"'
wait "$low_pid"
grep -q '"done": true' "$lowstream"
python3 - "$lowref" "$lowstream" <<'PY'
import json, sys
ref = json.load(open(sys.argv[1]))
lines = [json.loads(l) for l in open(sys.argv[2]) if l.strip()]
toks = [l["token"] for l in lines if "token" in l]
done = [l for l in lines if l.get("done")]
assert done, "low stream never finished: %s" % lines[-3:]
assert len(toks) == 40, "expected 40 streamed tokens, got %d" % len(toks)
assert toks == ref["tokens"][ref["prompt_len"]:], \
    "preempted stream diverged from the uncontended run"
assert done[0]["text"] == ref["text"], "decoded text diverged across preemption"
PY
curl -sf "$base3/stats" | python3 -c '
import json, sys
s = json.load(sys.stdin)
assert s["engine"]["preempted"] >= 1, "vip arrival never preempted the low lane: %s" % s
assert s["engine"]["resumed"] >= 1, "preempted lane never resumed: %s" % s
assert s["engine"]["resume_recompute_tokens"] >= 1, s
assert s["free_blocks"] == s["max_blocks"], "leaked KV blocks across preemption: %s" % s
'
curl -sf "$base3/metrics" | grep -q "^kurtail_requests_preempted_total"
kill -TERM "$pid3"
status=0
wait "$pid3" || status=$?
if [[ "$status" -ne 0 ]]; then
  echo "daemon_smoke: pressure daemon exited with status $status after SIGTERM" >&2
  cat "$log3" >&2
  exit 1
fi
pid3=""
echo "daemon_smoke: kv pressure — low stream paused, vip admitted, bitwise resume, no leak"

# SIGTERM → graceful drain → clean exit
kill -TERM "$pid"
status=0
wait "$pid" || status=$?
if [[ "$status" -ne 0 ]]; then
  echo "daemon_smoke: daemon exited with status $status after SIGTERM" >&2
  exit 1
fi
grep -q "drained clean" "$log"
trap - EXIT
rm -f "$log"
echo "daemon_smoke: SIGTERM drained clean"
