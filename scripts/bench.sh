#!/usr/bin/env bash
# Build release, run the kernel + serve benchmarks, and drop
# BENCH_kernels.json / BENCH_serve.json at the repo root so the perf
# trajectories are tracked PR-over-PR (see rust/README.md for schemas).
# This is the single bench driver: CI's bench-gate job runs it and then
# gates the output with scripts/check_bench.sh against BENCH_baseline/.
#
# Usage:  scripts/bench.sh            # full run
#         KURTAIL_THREADS=8 scripts/bench.sh
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
export KURTAIL_BENCH_JSON="${KURTAIL_BENCH_JSON:-$repo_root/BENCH_kernels.json}"
export KURTAIL_BENCH_SERVE_JSON="${KURTAIL_BENCH_SERVE_JSON:-$repo_root/BENCH_serve.json}"

cd "$repo_root/rust"
cargo build --release
cargo bench --bench kernels
cargo bench --bench serve

echo "--- BENCH_kernels.json summary ---"
# speedup lines for a quick human read; the JSON is the artifact
grep -o '"kernel": "[^"]*"\|"dim": [0-9]*\|"speedup": [0-9.]*' "$KURTAIL_BENCH_JSON" \
  | paste - - - || true
echo "wrote $KURTAIL_BENCH_JSON"

echo "--- BENCH_serve.json summary ---"
grep -o '"lanes": [0-9]*\|"tok_s": [0-9.]*\|"speedup_vs_lane1": [0-9.]*\|"int_gemm_speedup": [0-9.]*\|"arena_speedup": [0-9.]*\|"epilogue_fused_speedup": [0-9.]*\|"steal_speedup": [0-9.]*\|"reduction": [0-9.]*' \
  "$KURTAIL_BENCH_SERVE_JSON" | paste - - - - - - - || true
echo "wrote $KURTAIL_BENCH_SERVE_JSON"
