#!/usr/bin/env bash
# Build release, run the kernel benchmarks, and drop BENCH_kernels.json
# at the repo root so the scalar-vs-packed perf trajectory is tracked
# PR-over-PR (see rust/README.md for the schema).
#
# Usage:  scripts/bench.sh            # full run
#         KURTAIL_THREADS=8 scripts/bench.sh
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
export KURTAIL_BENCH_JSON="${KURTAIL_BENCH_JSON:-$repo_root/BENCH_kernels.json}"

cd "$repo_root/rust"
cargo build --release
cargo bench --bench kernels

echo "--- BENCH_kernels.json summary ---"
# speedup lines for a quick human read; the JSON is the artifact
grep -o '"kernel": "[^"]*"\|"dim": [0-9]*\|"speedup": [0-9.]*' "$KURTAIL_BENCH_JSON" \
  | paste - - - || true
echo "wrote $KURTAIL_BENCH_JSON"
