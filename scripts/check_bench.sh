#!/usr/bin/env bash
# Bench-regression gate: compare the current BENCH_kernels.json /
# BENCH_serve.json (written by scripts/bench.sh) against the committed
# snapshots in BENCH_baseline/ and fail when a tracked headline metric
# regresses by 10% or more (ROADMAP: "regressions ≥ 10% should block").
#
# Tracked metrics (all dimensionless ratios, so they transfer across
# hosts better than raw ns):
#   * kernels: matmul@1024 speedup, gram@1024 speedup
#     (packed-parallel vs the scalar seed kernel)
#   * serve:   runs[lanes=16].speedup_vs_lane1   (continuous batching)
#              runs[lanes=16].int_gemm_speedup   (int vs f32-dequant GEMM)
#              runs[lanes=16].arena_speedup      (arena+panel vs the PR-3
#                                                 fresh-alloc decode path)
#              runs[lanes=16].epilogue_fused_speedup
#                                                (fused column-major GEMM
#                                                 epilogues vs the PR-4
#                                                 serial-flip path)
#              runs[lanes=16].p99_ttft_ms        (open-loop Poisson load
#                                                 through the daemon host;
#                                                 LOWER is better — gated
#                                                 as a ceiling, not a floor)
#              runs[lanes=16].hi_pri_p99_ttft_ms (high-class TTFT under a
#                                                 low-class flood through
#                                                 the priority scheduler;
#                                                 LOWER is better — gated
#                                                 as a ceiling)
#              runs[lanes=16].fairness_ratio     (low-class p99 TTFT over
#                                                 high-class p99 TTFT in
#                                                 the same overload stage;
#                                                 a FLOOR — collapsing
#                                                 toward 1 means priority
#                                                 admission stopped
#                                                 working)
#              runs[lanes=16].obs_overhead       (telemetry cost: obs-off
#                                                 tok/s over obs-on − 1;
#                                                 ABSOLUTE ceiling 0.02 —
#                                                 the obs layer may never
#                                                 cost more than 2%)
#              runs[lanes=16].prefix_hit_ratio   (shared-prefix stage:
#                                                 prompt tokens served from
#                                                 shared KV blocks over all
#                                                 prompt tokens — a FLOOR;
#                                                 a broken prefix index
#                                                 collapses it toward 0)
#              runs[lanes=16].completed_under_pressure_ratio
#                                                (KV-pressure stage:
#                                                 completions over offered
#                                                 requests while half the
#                                                 pool is withheld and a
#                                                 high-class tenant preempts
#                                                 low lanes — a FLOOR; any
#                                                 drop means preempted
#                                                 streams were dropped, not
#                                                 paused and resumed)
#
# Usage:  scripts/check_bench.sh            # gate current vs baseline
#         scripts/check_bench.sh --update   # refresh BENCH_baseline/
#                                           # from the current files
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
kernels="${KURTAIL_BENCH_JSON:-$repo_root/BENCH_kernels.json}"
serve="${KURTAIL_BENCH_SERVE_JSON:-$repo_root/BENCH_serve.json}"
baseline_dir="$repo_root/BENCH_baseline"

for f in "$kernels" "$serve"; do
  if [[ ! -f "$f" ]]; then
    echo "check_bench: missing $f — run scripts/bench.sh first" >&2
    exit 2
  fi
done

if [[ "${1:-}" == "--update" ]]; then
  mkdir -p "$baseline_dir"
  cp "$kernels" "$baseline_dir/BENCH_kernels.json"
  cp "$serve" "$baseline_dir/BENCH_serve.json"
  echo "check_bench: baselines refreshed in $baseline_dir/"
  exit 0
fi

python3 - "$kernels" "$serve" "$baseline_dir" <<'PY'
import json, sys

kernels_path, serve_path, baseline_dir = sys.argv[1:4]
TOLERANCE = 0.10  # fail at >= 10% regression


def load(path):
    with open(path) as f:
        return json.load(f)


def kernel_speedup(doc, kernel, dim):
    for c in doc.get("comparisons", []):
        if c.get("kernel") == kernel and c.get("dim") == dim:
            return float(c["speedup"])
    raise KeyError(f"no comparison entry for {kernel}@{dim}")


def serve_run_metric(doc, lanes, field):
    for r in doc.get("runs", []):
        if r.get("lanes") == lanes:
            return float(r[field])
    raise KeyError(f"no serve run with lanes={lanes}")


cur_k, cur_s = load(kernels_path), load(serve_path)
base_k = load(f"{baseline_dir}/BENCH_kernels.json")
base_s = load(f"{baseline_dir}/BENCH_serve.json")

# (name, extractor, current args, baseline args, direction): "higher"
# gates a floor at base*(1-TOL), "lower" a ceiling at base*(1+TOL)
metrics = [
    ("kernels: matmul@1024 speedup", kernel_speedup, (cur_k, "matmul", 1024), (base_k, "matmul", 1024), "higher"),
    ("kernels: gram@1024 speedup", kernel_speedup, (cur_k, "gram", 1024), (base_k, "gram", 1024), "higher"),
    ("serve: lanes=16 speedup_vs_lane1", serve_run_metric, (cur_s, 16, "speedup_vs_lane1"), (base_s, 16, "speedup_vs_lane1"), "higher"),
    ("serve: lanes=16 int_gemm_speedup", serve_run_metric, (cur_s, 16, "int_gemm_speedup"), (base_s, 16, "int_gemm_speedup"), "higher"),
    ("serve: lanes=16 arena_speedup", serve_run_metric, (cur_s, 16, "arena_speedup"), (base_s, 16, "arena_speedup"), "higher"),
    ("serve: lanes=16 epilogue_fused_speedup", serve_run_metric, (cur_s, 16, "epilogue_fused_speedup"), (base_s, 16, "epilogue_fused_speedup"), "higher"),
    ("serve: lanes=16 p99_ttft_ms", serve_run_metric, (cur_s, 16, "p99_ttft_ms"), (base_s, 16, "p99_ttft_ms"), "lower"),
    ("serve: lanes=16 hi_pri_p99_ttft_ms", serve_run_metric, (cur_s, 16, "hi_pri_p99_ttft_ms"), (base_s, 16, "hi_pri_p99_ttft_ms"), "lower"),
    ("serve: lanes=16 fairness_ratio", serve_run_metric, (cur_s, 16, "fairness_ratio"), (base_s, 16, "fairness_ratio"), "higher"),
    ("serve: lanes=16 prefix_hit_ratio", serve_run_metric, (cur_s, 16, "prefix_hit_ratio"), (base_s, 16, "prefix_hit_ratio"), "higher"),
    ("serve: lanes=16 completed_under_pressure_ratio", serve_run_metric, (cur_s, 16, "completed_under_pressure_ratio"), (base_s, 16, "completed_under_pressure_ratio"), "higher"),
]

failures = []
for name, fn, cur_args, base_args, direction in metrics:
    try:
        base = fn(*base_args)
    except KeyError as e:
        # a metric absent from the baseline is not yet gated (lets the
        # baseline trail new bench fields by one refresh)
        print(f"  SKIP {name}: baseline has no value ({e})")
        continue
    try:
        cur = fn(*cur_args)
    except KeyError as e:
        # a gated metric the current bench no longer emits is itself a
        # regression (the headline disappeared), not a crash
        print(f"  REGRESSION  {name}: missing from current bench output ({e})")
        failures.append(f"{name} (missing from current output)")
        continue
    if direction == "higher":
        bound = base * (1.0 - TOLERANCE)
        ok = cur >= bound
        kind = "floor"
    else:
        bound = base * (1.0 + TOLERANCE)
        ok = cur <= bound
        kind = "ceiling"
    status = "ok" if ok else "REGRESSION"
    print(f"  {status:>10}  {name}: current {cur:.3f} vs baseline {base:.3f} ({kind} {bound:.3f})")
    if not ok:
        failures.append(name)

# absolute gates: fixed bounds rather than baseline-relative ones. The
# obs overhead contract is "telemetry costs <= 2%", full stop — a slow
# baseline must not launder a slower current run. Skipped (not failed)
# when the current bench predates the field.
OBS_OVERHEAD_CEILING = 0.02
try:
    overhead = serve_run_metric(cur_s, 16, "obs_overhead")
except KeyError:
    print("  SKIP serve: lanes=16 obs_overhead: current bench has no value")
else:
    ok = overhead <= OBS_OVERHEAD_CEILING
    status = "ok" if ok else "REGRESSION"
    print(f"  {status:>10}  serve: lanes=16 obs_overhead: current {overhead:.4f} (absolute ceiling {OBS_OVERHEAD_CEILING:.2f})")
    if not ok:
        failures.append("serve: lanes=16 obs_overhead over the 2% absolute ceiling")

if failures:
    print(f"check_bench: {len(failures)} metric(s) regressed >= {TOLERANCE:.0%}:", file=sys.stderr)
    for name in failures:
        print(f"  - {name}", file=sys.stderr)
    print("if intentional, refresh with scripts/check_bench.sh --update", file=sys.stderr)
    sys.exit(1)
print("check_bench: all tracked metrics within tolerance")
PY
